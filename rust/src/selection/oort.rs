//! Oort participant selection (Lai et al., OSDI'21) — the paper's main
//! baseline (§2.2). Reimplemented from the Oort paper's description:
//!
//! * **statistical utility** of learner i: |B_i| * sqrt(mean of squared
//!   per-step training losses) from its latest participation;
//! * **system utility**: (T / t_i)^alpha penalty when the learner's task
//!   duration t_i exceeds the developer-preferred round duration T;
//! * **exploration/exploitation**: epsilon-greedy over never-explored
//!   learners, with epsilon decaying per round;
//! * **pacer**: when accumulated exploited utility stops improving, relax T
//!   by a step (trading longer rounds for unexplored/slow learners).

use std::collections::HashMap;

use super::{RoundFeedback, SelectionCtx, Selector};

#[derive(Clone, Copy, Debug)]
pub struct OortConfig {
    pub epsilon0: f64,
    pub epsilon_decay: f64,
    pub epsilon_min: f64,
    /// System-utility exponent (Oort's alpha).
    pub alpha: f64,
    /// Initial preferred round duration T (seconds).
    pub preferred_duration: f64,
    /// Pacer window W (rounds) and step (seconds).
    pub pacer_window: usize,
    pub pacer_step: f64,
}

impl Default for OortConfig {
    fn default() -> Self {
        OortConfig {
            epsilon0: 0.9,
            epsilon_decay: 0.98,
            epsilon_min: 0.2,
            alpha: 2.0,
            preferred_duration: 60.0,
            pacer_window: 20,
            pacer_step: 10.0,
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct LearnerStats {
    stat_util: f64,
    duration: f64,
    last_round: usize,
}

pub struct OortSelector {
    cfg: OortConfig,
    epsilon: f64,
    explored: HashMap<usize, LearnerStats>,
    /// Exploited utility accumulated in the current/previous pacer windows.
    window_util: f64,
    prev_window_util: f64,
    rounds_in_window: usize,
    preferred_duration: f64,
}

impl Default for OortSelector {
    fn default() -> Self {
        Self::new(OortConfig::default())
    }
}

impl OortSelector {
    pub fn new(cfg: OortConfig) -> Self {
        OortSelector {
            epsilon: cfg.epsilon0,
            preferred_duration: cfg.preferred_duration,
            cfg,
            explored: HashMap::new(),
            window_util: 0.0,
            prev_window_util: 0.0,
            rounds_in_window: 0,
        }
    }

    /// Combined utility of an explored learner.
    fn utility(&self, id: usize, expected_duration: f64) -> f64 {
        let s = &self.explored[&id];
        let stat = s.stat_util;
        let dur = if s.duration > 0.0 { s.duration } else { expected_duration };
        let sys = if dur > self.preferred_duration {
            (self.preferred_duration / dur).powf(self.cfg.alpha)
        } else {
            1.0
        };
        stat * sys
    }

    pub fn current_preferred_duration(&self) -> f64 {
        self.preferred_duration
    }
}

impl Selector for OortSelector {
    fn name(&self) -> &'static str {
        "oort"
    }

    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize> {
        let k = ctx.target.min(ctx.candidates.len());
        let mut picked = Vec::with_capacity(k);

        let (explored, unexplored): (Vec<&super::Candidate>, Vec<&super::Candidate>) = ctx
            .candidates
            .iter()
            .partition(|c| self.explored.contains_key(&c.id));

        // exploration: epsilon share from never-explored learners (random)
        let n_explore = ((k as f64) * self.epsilon).round() as usize;
        let n_explore = n_explore.min(unexplored.len());
        for i in ctx.rng.choose_k(unexplored.len(), n_explore) {
            picked.push(unexplored[i].id);
        }

        // exploitation: top utility among explored
        let n_exploit = k - picked.len();
        let mut ranked: Vec<(f64, usize)> = explored
            .iter()
            .map(|c| (self.utility(c.id, c.expected_duration), c.id))
            .collect();
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for (u, id) in ranked.into_iter().take(n_exploit) {
            self.window_util += u;
            picked.push(id);
        }

        // backfill from unexplored if explored pool was too small
        if picked.len() < k {
            let already: std::collections::HashSet<usize> = picked.iter().copied().collect();
            for c in unexplored {
                if picked.len() >= k {
                    break;
                }
                if !already.contains(&c.id) {
                    picked.push(c.id);
                }
            }
        }

        self.epsilon = (self.epsilon * self.cfg.epsilon_decay).max(self.cfg.epsilon_min);
        picked
    }

    fn feedback(&mut self, fb: &RoundFeedback) {
        for &(id, stat_util, duration) in fb.completed {
            let e = self.explored.entry(id).or_default();
            e.stat_util = stat_util;
            e.duration = duration;
            e.last_round = fb.round;
        }
        // learners that missed the deadline get their utility dampened
        for id in fb.missed {
            if let Some(e) = self.explored.get_mut(id) {
                e.stat_util *= 0.5;
            }
        }
        // pacer: if exploited utility in this window dropped vs the
        // previous one, allow longer rounds to reach new learners.
        self.rounds_in_window += 1;
        if self.rounds_in_window >= self.cfg.pacer_window {
            if self.window_util < 0.95 * self.prev_window_util {
                self.preferred_duration += self.cfg.pacer_step;
            }
            self.prev_window_util = self.window_util;
            self.window_util = 0.0;
            self.rounds_in_window = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::Candidate;
    use crate::util::rng::Rng;

    fn candidates(n: usize) -> Vec<Candidate> {
        (0..n)
            .map(|i| Candidate {
                id: i,
                avail_prob: 1.0,
                // learner i is slower with larger i
                expected_duration: 10.0 + 5.0 * i as f64,
            })
            .collect()
    }

    fn run_round(s: &mut OortSelector, cands: &[Candidate], round: usize, seed: u64) -> Vec<usize> {
        let mut rng = Rng::new(seed);
        let mut ctx = SelectionCtx {
            round,
            now: 0.0,
            target: 5,
            candidates: cands,
            rng: &mut rng,
        };
        s.select(&mut ctx)
    }

    #[test]
    fn explores_initially_exploits_later() {
        let cands = candidates(40);
        // low exploration so the exploitation behaviour is visible quickly
        let mut s = OortSelector::new(OortConfig { epsilon0: 0.2, ..OortConfig::default() });
        // round 0: nothing explored -> all picks are exploration/backfill
        let picked0 = run_round(&mut s, &cands, 0, 1);
        assert_eq!(picked0.len(), 5);

        // feed back high utility for fast learners 0..5, low for others
        for r in 0..50 {
            let completed: Vec<(usize, f64, f64)> = (0..10)
                .map(|id| {
                    let util = if id < 5 { 100.0 } else { 1.0 };
                    (id, util, 10.0 + 5.0 * id as f64)
                })
                .collect();
            s.feedback(&RoundFeedback {
                round: r,
                completed: &completed,
                missed: &[],
                round_duration: 60.0,
            });
        }
        // epsilon has decayed; exploitation should prefer ids 0..5
        let mut hits = 0;
        for r in 100..120 {
            for id in run_round(&mut s, &cands, r, r as u64) {
                if id < 5 {
                    hits += 1;
                }
            }
        }
        assert!(hits > 50, "oort should exploit high-utility fast learners, hits={hits}");
    }

    #[test]
    fn exploitation_ranks_strictly_by_utility() {
        // epsilon pinned to 0 => pure exploitation: the pick must be the
        // top-`target` explored learners ordered by descending utility
        let mut s = OortSelector::new(OortConfig {
            epsilon0: 0.0,
            epsilon_min: 0.0,
            ..OortConfig::default()
        });
        let cands: Vec<Candidate> = (0..8)
            .map(|i| Candidate { id: i, avail_prob: 1.0, expected_duration: 10.0 })
            .collect();
        // all durations are below the preferred duration, so ranking is by
        // statistical utility alone
        s.feedback(&RoundFeedback {
            round: 0,
            completed: &[
                (3, 50.0, 10.0),
                (1, 40.0, 10.0),
                (6, 30.0, 10.0),
                (0, 20.0, 10.0),
                (4, 10.0, 10.0),
                (7, 5.0, 10.0),
            ],
            missed: &[],
            round_duration: 60.0,
        });
        let picked = run_round(&mut s, &cands, 1, 42);
        assert_eq!(picked, vec![3, 1, 6, 0, 4]);
    }

    #[test]
    fn system_utility_penalizes_slow_learners() {
        let mut s = OortSelector::default();
        s.explored.insert(1, LearnerStats { stat_util: 10.0, duration: 30.0, last_round: 0 });
        s.explored.insert(2, LearnerStats { stat_util: 10.0, duration: 240.0, last_round: 0 });
        let fast = s.utility(1, 30.0);
        let slow = s.utility(2, 240.0);
        assert!(fast > 3.0 * slow, "fast {fast} vs slow {slow}");
    }

    #[test]
    fn pacer_relaxes_preferred_duration_on_utility_drop() {
        let mut s = OortSelector::new(OortConfig {
            pacer_window: 2,
            ..OortConfig::default()
        });
        let t0 = s.current_preferred_duration();
        // window 1: high exploited utility
        s.window_util = 100.0;
        for r in 0..2 {
            s.feedback(&RoundFeedback {
                round: r,
                completed: &[],
                missed: &[],
                round_duration: 60.0,
            });
        }
        // window 2: low utility -> pacer must step T up
        s.window_util = 10.0;
        for r in 2..4 {
            s.feedback(&RoundFeedback {
                round: r,
                completed: &[],
                missed: &[],
                round_duration: 60.0,
            });
        }
        assert!(s.current_preferred_duration() > t0);
    }

    #[test]
    fn epsilon_decays_to_floor() {
        let cands = candidates(10);
        let mut s = OortSelector::default();
        for r in 0..500 {
            run_round(&mut s, &cands, r, r as u64);
        }
        assert!((s.epsilon - s.cfg.epsilon_min).abs() < 1e-9);
    }

    #[test]
    fn per_arrival_feedback_updates_exploration_state() {
        // async-regime hooks: each arrival registers the learner as
        // explored with its observed utility; each departure dampens it
        let mut s = OortSelector::default();
        s.on_arrival(0, (3, 12.0, 20.0), 60.0);
        assert!((s.explored[&3].stat_util - 12.0).abs() < 1e-12);
        assert!((s.explored[&3].duration - 20.0).abs() < 1e-12);
        s.on_departure(1, 3, 60.0);
        assert!((s.explored[&3].stat_util - 6.0).abs() < 1e-12);
    }

    #[test]
    fn missed_deadline_dampens_utility() {
        let mut s = OortSelector::default();
        s.explored.insert(7, LearnerStats { stat_util: 8.0, duration: 10.0, last_round: 0 });
        s.feedback(&RoundFeedback {
            round: 1,
            completed: &[],
            missed: &[7],
            round_duration: 60.0,
        });
        assert!((s.explored[&7].stat_util - 4.0).abs() < 1e-12);
    }
}
