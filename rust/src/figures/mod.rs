//! The per-figure experiment harness: one entry point per table/figure of
//! the paper's evaluation (DESIGN.md §5 maps each to its configs).
//!
//! Every harness prints the same rows/series the paper reports (labels,
//! accuracy-vs-resources trajectories, waste fractions, unique-participant
//! rates) and writes the raw series to `results/<id>.json`. Populations and
//! round counts are scaled down by default for a CPU testbed; pass
//! `--scale 1.0` for paper-scale runs.

pub mod ablations;
pub mod configs;
pub mod runner;
pub mod static_figs;

use anyhow::{anyhow, Result};

use runner::FigureOpts;

/// Run one figure/table by id ("2", "3", ..., "20", "21", "t1", "t2",
/// "forecast"). "all" runs everything.
pub fn run(id: &str, opts: &FigureOpts) -> Result<()> {
    match id {
        "2" => configs::fig2(opts),
        "3" => configs::fig3(opts),
        "4" => configs::fig4(opts),
        "5" => static_figs::fig5(opts),
        "6" => configs::fig6(opts),
        "7" => configs::fig7(opts),
        "8" => configs::fig8(opts),
        "9" => configs::fig9(opts),
        "10" => configs::fig10(opts),
        "11" => configs::fig11(opts),
        "12" => configs::fig12(opts),
        "13" => static_figs::fig13(opts),
        "14" => static_figs::fig14(opts),
        "15" => configs::fig15_18(opts, "nlp", true),
        "16" => configs::fig15_18(opts, "cifar", true),
        "17" => configs::fig15_18(opts, "nlp", false),
        "18" => configs::fig15_18(opts, "openimage", false),
        "19" => configs::fig19(opts),
        "20" => configs::fig20(opts),
        "21" => static_figs::fig21(opts),
        "t1" | "table1" => static_figs::table1(opts),
        "t2" | "table2" => configs::table2(opts),
        "forecast" => static_figs::forecast_eval(opts),
        "all" => {
            for id in [
                "13", "14", "21", "t1", "forecast", "5", "2", "3", "4", "6", "7", "8",
                "9", "10", "11", "12", "16", "19", "20", "t2",
            ] {
                println!("\n================ figure {id} ================");
                run(id, opts)?;
            }
            Ok(())
        }
        "ablations" => ablations::run_all(opts),
        other => {
            if let Some(name) = other.strip_prefix("ablation-") {
                return ablations::run(name, opts);
            }
            Err(anyhow!(
                "unknown figure id '{other}' (try 2..21, t1, t2, forecast, ablations, ablation-<knob>, all)"
            ))
        }
    }
}
