//! Config builders for every dynamic (training-run) figure of the paper.
//! Paper-scale parameters are noted inline; `opts.scale` shrinks populations
//! and round counts for the CPU testbed (`--scale 1.0` restores them).

use anyhow::Result;

use super::runner::{print_resource_table, print_series, run_set, FigureOpts};
use crate::aggregation::scaling::ScalingRule;
use crate::config::{preset, AvailMode, ExpConfig, RoundMode};
use crate::data::partition::{LabelSkew, PartitionScheme};
use crate::learners::HardwareScenario;

pub(crate) fn speech(opts: &FigureOpts) -> ExpConfig {
    let mut c = preset("speech").unwrap();
    c.total_learners = opts.scaled(1000, 200);
    c.rounds = opts.scaled(500, 100);
    // evaluation cadence scaled to round count (eval cost is significant
    // on a single-core testbed)
    c.eval_every = (c.rounds / 15).max(5);
    if opts.scale < 0.2 {
        // fast mode: keep the check-in pool a healthy multiple of the
        // selection target — at paper scale the 5-round cooldown holds
        // out ~5% of the population, at 1/8 scale it would hold out most
        // of the available set and degenerate every selector to "take all"
        c.cooldown_rounds = 2;
    }
    c
}

fn label_limited(skew: LabelSkew) -> PartitionScheme {
    PartitionScheme::LabelLimited { labels: 0, skew }
}

const MAPPINGS_4: [(&str, PartitionScheme); 4] = [
    ("fedscale", PartitionScheme::FedScale),
    ("balanced", PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Balanced }),
    ("uniform", PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Uniform }),
    ("zipf", PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Zipf }),
];

/// Fig. 2: SAFA vs SAFA+O vs FedAvg-Random(10/100), resource usage &
/// waste under DL+DynAvail (paper: 1000 learners, deadline 100 s,
/// staleness 5, target 10%).
pub fn fig2(opts: &FigureOpts) -> Result<()> {
    let base = |label: &str| -> ExpConfig {
        let mut c = speech(opts);
        c.label = label.into();
        c.mode = RoundMode::Deadline { deadline: 100.0 };
        c.avail = AvailMode::DynAvail;
        c.partition = PartitionScheme::FedScale;
        c.rounds = opts.scaled(300, 80);
        // heavier local tasks (the paper's 1-epoch Google Speech pass is
        // minutes on slow phones): deep stragglers against the 100 s
        // deadline are exactly what Fig. 2 measures
        c.mean_samples = 300;
        c
    };
    let mut safa = base("SAFA");
    safa.selector = "safa".into();
    safa.use_saa = true;
    safa.staleness_threshold = Some(5);
    safa.scaling = ScalingRule::Equal;
    safa.safa_target_ratio = 0.1;

    let mut safa_o = safa.clone();
    safa_o.label = "SAFA+O".into();
    safa_o.oracle = true;

    let mut fed10 = base("FedAvg-Random-10");
    fed10.selector = "random".into();
    fed10.target_participants = 10;

    let mut fed100 = base("FedAvg-Random-100");
    fed100.selector = "random".into();
    fed100.target_participants = opts.scaled(100, 20);

    let results = run_set("fig2", "Fig. 2: SAFA resource wastage", vec![safa, safa_o, fed10, fed100], opts)?;
    print_resource_table(&results);
    print_series(&results, 6);
    println!(
        "  [paper shape: SAFA ~5x the resources of SAFA+O at equal accuracy, ~80% waste;\n   FedAvg-10 ~5x slower to the same accuracy, FedAvg-100 trades resources for time]"
    );
    Ok(())
}

/// Fig. 3: Oort vs Random under IID and non-IID, AllAvail (selection bias).
pub fn fig3(opts: &FigureOpts) -> Result<()> {
    let mut configs = Vec::new();
    for (mname, part) in [
        ("iid", PartitionScheme::UniformIid),
        ("noniid", label_limited(LabelSkew::Uniform)),
    ] {
        for sel in ["oort", "random"] {
            let mut c = speech(opts);
            c.label = format!("{sel}-{mname}");
            c.selector = sel.into();
            c.avail = AvailMode::AllAvail;
            c.partition = part;
            c.rounds = opts.scaled(1000, 150);
            configs.push(c);
        }
    }
    let results = run_set("fig3", "Fig. 3: impact of data heterogeneity on selection", configs, opts)?;
    print_resource_table(&results);
    for r in &results {
        let unique = r.rounds.last().map(|x| x.unique_participants).unwrap_or(0);
        println!("  {:<28} unique participants: {}", r.label, unique);
    }
    println!("  [paper shape: Oort wins IID (system efficiency); Random wins non-IID (diversity)]");
    Ok(())
}

/// Fig. 4: availability impact on Random (AllAvail vs DynAvail, IID/non-IID).
pub fn fig4(opts: &FigureOpts) -> Result<()> {
    let mut configs = Vec::new();
    for (mname, part) in [
        ("iid", PartitionScheme::UniformIid),
        ("noniid", label_limited(LabelSkew::Uniform)),
    ] {
        for (aname, avail) in [("all", AvailMode::AllAvail), ("dyn", AvailMode::DynAvail)] {
            let mut c = speech(opts);
            c.label = format!("random-{mname}-{aname}");
            c.selector = "random".into();
            c.avail = avail;
            c.partition = part;
            configs.push(c);
        }
    }
    let results = run_set("fig4", "Fig. 4: impact of availability on model quality", configs, opts)?;
    print_resource_table(&results);
    println!("  [paper shape: ~no effect IID; ~10-point drop non-IID under DynAvail]");
    Ok(())
}

/// Fig. 6: selector comparison under OC+DynAvail across data mappings.
pub fn fig6(opts: &FigureOpts) -> Result<()> {
    for (mname, part) in MAPPINGS_4 {
        let mut configs = Vec::new();
        for sel in ["random", "oort", "priority", "relay"] {
            let mut c = speech(opts);
            c.label = format!("{sel}-{mname}");
            c.avail = AvailMode::DynAvail;
            c.partition = part;
            if sel == "relay" {
                c = c.relay();
                c.label = format!("relay-{mname}");
            } else {
                c.selector = sel.into();
            }
            configs.push(c);
        }
        let results = run_set(
            &format!("fig6_{mname}"),
            &format!("Fig. 6 ({mname}): selectors under OC+DynAvail"),
            configs,
            opts,
        )?;
        print_resource_table(&results);
        print_series(&results, 5);
    }
    println!("  [paper shape: RELAY best accuracy at least resources; Priority > Random non-IID]");
    Ok(())
}

/// Fig. 7: RELAY vs SAFA under DL+DynAvail (fedscale + non-IID).
pub fn fig7(opts: &FigureOpts) -> Result<()> {
    for (mname, part) in [
        ("fedscale", PartitionScheme::FedScale),
        ("noniid", label_limited(LabelSkew::Uniform)),
    ] {
        let mut safa = speech(opts);
        safa.label = format!("SAFA-{mname}");
        safa.selector = "safa".into();
        safa.use_saa = true;
        safa.scaling = ScalingRule::Equal;
        safa.staleness_threshold = Some(5);
        safa.safa_target_ratio = 0.1;
        safa.mode = RoundMode::Deadline { deadline: 100.0 };
        safa.avail = AvailMode::DynAvail;
        safa.partition = part;
        safa.server_opt = "fedavg".into(); // paper: FedAvg underneath
        safa.rounds = opts.scaled(300, 80);

        let mut relay = safa.clone();
        relay.label = format!("RELAY-{mname}");
        relay.selector = "priority".into();
        relay.scaling = ScalingRule::Relay { beta: 0.35 };
        relay.apt = false;
        relay.target_participants = opts.scaled(100, 20); // pre-selects 100
        relay.safa_target_ratio = 0.8;

        let results = run_set(
            &format!("fig7_{mname}"),
            &format!("Fig. 7 ({mname}): RELAY vs SAFA"),
            vec![safa, relay],
            opts,
        )?;
        print_resource_table(&results);
        print_series(&results, 5);
    }
    println!("  [paper shape: comparable run-times; RELAY ~20% fewer resources (fedscale), ~60% fewer + ~10 points (non-IID)]");
    Ok(())
}

/// Fig. 8: Adaptive Participant Target with 50 participants, OC.
pub fn fig8(opts: &FigureOpts) -> Result<()> {
    for (aname, avail) in [("dyn", AvailMode::DynAvail), ("all", AvailMode::AllAvail)] {
        let mut configs = Vec::new();
        for sel in ["oort", "random", "relay", "relay+apt"] {
            let mut c = speech(opts);
            c.avail = avail;
            c.partition = label_limited(LabelSkew::Uniform);
            c.target_participants = opts.scaled(50, 12);
            c.rounds = opts.scaled(300, 80);
            match sel {
                "relay" => {
                    c = c.relay();
                    c.apt = false;
                }
                "relay+apt" => c = c.relay(),
                s => c.selector = s.into(),
            }
            c.label = format!("{sel}-{aname}");
            configs.push(c);
        }
        let results = run_set(
            &format!("fig8_{aname}"),
            &format!("Fig. 8 ({aname}): Adaptive Participant Target"),
            configs,
            opts,
        )?;
        print_resource_table(&results);
    }
    println!("  [paper shape: RELAY(+APT) higher quality at lower resources; APT trades run-time for fewer resources]");
    Ok(())
}

/// Fig. 9: stale aggregation under OC+AllAvail (accuracy vs ROUNDS).
pub fn fig9(opts: &FigureOpts) -> Result<()> {
    for (mname, part) in [
        ("fedscale", PartitionScheme::FedScale),
        ("uniform", label_limited(LabelSkew::Uniform)),
        ("zipf", label_limited(LabelSkew::Zipf)),
    ] {
        let mut configs = Vec::new();
        for sel in ["relay", "oort", "random"] {
            let mut c = speech(opts);
            c.avail = AvailMode::AllAvail;
            c.partition = part;
            if sel == "relay" {
                c = c.relay();
                c.apt = false; // isolate SAA (paper: RELAY ~ Random runtime here)
            } else {
                c.selector = sel.into();
            }
            c.label = format!("{sel}-{mname}");
            configs.push(c);
        }
        let results = run_set(
            &format!("fig9_{mname}"),
            &format!("Fig. 9 ({mname}): stale aggregation, OC+AllAvail"),
            configs,
            opts,
        )?;
        for r in &results {
            let pts: Vec<String> = r
                .accuracy_vs_rounds()
                .iter()
                .step_by(4)
                .map(|(rd, a)| format!("r{rd}:{:.0}%", a * 100.0))
                .collect();
            println!("  {:<28} {}", r.label, pts.join("  "));
        }
    }
    println!("  [paper shape: RELAY's SAA boosts statistical efficiency, most in non-IID]");
    Ok(())
}

/// Fig. 10 (YoGi) — weight-scaling rules across 5 mappings.
pub fn fig10(opts: &FigureOpts) -> Result<()> {
    scaling_rule_figure(opts, "yogi", "fig10")
}

/// Fig. 19 (FedAvg) — same sweep with FedAvg underneath (Appendix D.4).
pub fn fig19(opts: &FigureOpts) -> Result<()> {
    scaling_rule_figure(opts, "fedavg", "fig19")
}

fn scaling_rule_figure(opts: &FigureOpts, server_opt: &str, name: &str) -> Result<()> {
    let mut mappings: Vec<(&str, PartitionScheme)> = vec![
        ("iid", PartitionScheme::UniformIid),
        ("fedscale", PartitionScheme::FedScale),
        ("balanced", label_limited(LabelSkew::Balanced)),
        ("uniform", label_limited(LabelSkew::Uniform)),
        ("zipf", label_limited(LabelSkew::Zipf)),
    ];
    if opts.scale < 0.2 {
        // fast mode: one IID + two non-IID mappings carry the figure's shape
        mappings = vec![
            ("iid", PartitionScheme::UniformIid),
            ("uniform", label_limited(LabelSkew::Uniform)),
            ("zipf", label_limited(LabelSkew::Zipf)),
        ];
    }
    for (mname, part) in mappings {
        let mut configs = Vec::new();
        for rule in ["equal", "dynsgd", "adasgd", "relay"] {
            let mut c = speech(opts);
            c = c.relay();
            c.apt = false;
            c.scaling = ScalingRule::parse(rule).unwrap();
            c.avail = AvailMode::DynAvail;
            c.partition = part;
            c.server_opt = server_opt.into();
            c.rounds = opts.scaled(300, 80);
            c.label = format!("{rule}-{mname}");
            configs.push(c);
        }
        let results = run_set(
            &format!("{name}_{mname}"),
            &format!("{name} ({mname}): stale-weight scaling rules ({server_opt})"),
            configs,
            opts,
        )?;
        for r in &results {
            let last = r.accuracy_vs_rounds();
            let tail: Vec<String> = last
                .iter()
                .rev()
                .take(3)
                .map(|(rd, a)| format!("r{rd}:{:.1}%", a * 100.0))
                .collect();
            println!("  {:<28} final: {}", r.label, tail.join("  "));
        }
    }
    println!("  [paper shape: RELAY's Eq.2 rule consistently best; others inconsistent in non-IID]");
    Ok(())
}

/// Fig. 11: large-scale populations (3x learners), SAFA vs RELAY.
pub fn fig11(opts: &FigureOpts) -> Result<()> {
    for (mname, part) in [
        ("iid", PartitionScheme::UniformIid),
        ("noniid", label_limited(LabelSkew::Uniform)),
    ] {
        let mut safa = speech(opts);
        safa.total_learners = opts.scaled(3000, 180);
        safa.label = format!("SAFA-3x-{mname}");
        safa.selector = "safa".into();
        safa.use_saa = true;
        safa.scaling = ScalingRule::Equal;
        safa.staleness_threshold = Some(5);
        safa.mode = RoundMode::Deadline { deadline: 100.0 };
        safa.avail = AvailMode::DynAvail;
        safa.partition = part;
        safa.server_opt = "fedavg".into();
        safa.rounds = opts.scaled(200, 60);

        let mut relay = safa.clone();
        relay.label = format!("RELAY-3x-{mname}");
        relay.selector = "priority".into();
        relay.scaling = ScalingRule::Relay { beta: 0.35 };
        relay.target_participants = opts.scaled(100, 20);
        relay.safa_target_ratio = 0.8;

        let results = run_set(
            &format!("fig11_{mname}"),
            &format!("Fig. 11 ({mname}): large-scale (3x population)"),
            vec![safa, relay],
            opts,
        )?;
        print_resource_table(&results);
    }
    println!("  [paper shape: SAFA's waste grows with population, worst in non-IID]");
    Ok(())
}

/// Fig. 12: future hardware advancements HS1-HS4, Oort vs RELAY.
pub fn fig12(opts: &FigureOpts) -> Result<()> {
    let mappings: Vec<(&str, PartitionScheme)> = if opts.scale < 0.2 {
        // fast mode: non-IID is where the paper's effect lives
        vec![("noniid", label_limited(LabelSkew::Uniform))]
    } else {
        vec![
            ("iid", PartitionScheme::UniformIid),
            ("noniid", label_limited(LabelSkew::Uniform)),
        ]
    };
    for (mname, part) in mappings {
        let mut configs = Vec::new();
        for hs in [
            HardwareScenario::Hs1,
            HardwareScenario::Hs2,
            HardwareScenario::Hs3,
            HardwareScenario::Hs4,
        ] {
            for sel in ["oort", "relay"] {
                let mut c = speech(opts);
                c.partition = part;
                c.avail = AvailMode::DynAvail;
                c.hardware = hs;
                c.rounds = opts.scaled(300, 80);
                if sel == "relay" {
                    c = c.relay();
                } else {
                    c.selector = sel.into();
                }
                c.label = format!("{sel}-{:?}-{mname}", hs).to_lowercase();
                configs.push(c);
            }
        }
        let results = run_set(
            &format!("fig12_{mname}"),
            &format!("Fig. 12 ({mname}): hardware advancement scenarios"),
            configs,
            opts,
        )?;
        print_resource_table(&results);
    }
    println!("  [paper shape: both gain IID; Oort degrades non-IID while RELAY gains]");
    Ok(())
}

/// Figs. 15-18: other benchmarks, RELAY vs Oort (OC + Dyn/AllAvail).
pub fn fig15_18(opts: &FigureOpts, benchmark: &str, dynavail: bool) -> Result<()> {
    let avail = if dynavail { AvailMode::DynAvail } else { AvailMode::AllAvail };
    let aname = if dynavail { "dyn" } else { "all" };
    let mut configs = Vec::new();
    for sel in ["oort", "relay"] {
        let mut c = preset(benchmark)?;
        c.total_learners = opts.scaled(1000, 150);
        c.rounds = opts.scaled(300, 80);
        c.avail = avail;
        c.partition = PartitionScheme::FedScale;
        if sel == "relay" {
            c = c.relay();
        } else {
            c.selector = sel.into();
        }
        c.label = format!("{sel}-{benchmark}-{aname}");
        configs.push(c);
    }
    let results = run_set(
        &format!("fig15_18_{benchmark}_{aname}"),
        &format!("Figs. 15-18 ({benchmark}, {aname}): RELAY vs Oort"),
        configs,
        opts,
    )?;
    print_resource_table(&results);
    for r in &results {
        if r.perplexity_metric {
            if let Some(last) = r.rounds.iter().rev().find_map(|x| x.test_loss) {
                println!("  {:<28} test perplexity: {:.2}", r.label, last.exp());
            }
        }
    }
    Ok(())
}

/// Fig. 20: long-run convergence, RELAY vs Oort (non-IID mappings).
pub fn fig20(opts: &FigureOpts) -> Result<()> {
    let mut configs = Vec::new();
    for sel in ["oort", "relay"] {
        let mut c = speech(opts);
        c.partition = label_limited(LabelSkew::Uniform);
        c.avail = AvailMode::DynAvail;
        c.rounds = opts.scaled(1500, 250);
        if sel == "relay" {
            c = c.relay();
        } else {
            c.selector = sel.into();
        }
        c.label = format!("{sel}-longrun");
        configs.push(c);
    }
    let results = run_set("fig20", "Fig. 20: convergence over long runs", configs, opts)?;
    print_resource_table(&results);
    print_series(&results, 8);
    println!("  [paper shape: RELAY converges up to ~20 points above Oort, with fewer resources]");
    Ok(())
}

/// Table 2: semi-centralized baselines per benchmark x mapping.
pub fn table2(opts: &FigureOpts) -> Result<()> {
    use crate::coordinator::centralized::run_centralized;
    println!("--- Table 2: semi-centralized baselines (10 learners, full participation) ---");
    println!(
        "  {:<12} {:<10} {:>8} {:>10} {:>8} {:>10}",
        "benchmark", "server", "iid", "label-unif", "zipf", "balanced"
    );
    let benches: Vec<&str> = if opts.scale >= 1.0 {
        vec!["speech", "cifar", "openimage", "nlp"]
    } else {
        vec!["speech", "cifar"]
    };
    let rounds = opts.scaled(150, 40);
    for b in benches {
        let mut row = Vec::new();
        for part in [
            PartitionScheme::UniformIid,
            label_limited(LabelSkew::Uniform),
            label_limited(LabelSkew::Zipf),
            label_limited(LabelSkew::Balanced),
        ] {
            let mut c = preset(b)?;
            c.partition = part;
            c.mean_samples = 400; // table 2 splits the full dataset over 10
            let exec = opts.executor(&c.variant)?;
            let r = run_centralized(&c, exec, rounds)?;
            let v = if c.variant == "nlp" {
                format!("{:.1}p", r.final_loss.exp()) // perplexity
            } else {
                format!("{:.1}%", 100.0 * r.final_accuracy)
            };
            row.push(v);
        }
        let server = preset(b)?.server_opt;
        println!(
            "  {:<12} {:<10} {:>8} {:>10} {:>8} {:>10}",
            b, server, row[0], row[1], row[2], row[3]
        );
    }
    println!("  [paper: speech 76.5 / 34.7 / 33.4 / 37.1 (top-5); shape = IID >> label-limited]");
    Ok(())
}
