//! Figures that analyze substrates rather than training runs: device
//! heterogeneity (Fig. 13), availability traces (Fig. 14), label coverage
//! (Fig. 21), the Table 1 preset summary, the Fig. 5 illustrative round
//! trace, and the §5.2 forecast-quality experiment.

use anyhow::Result;

use super::runner::FigureOpts;
use crate::config::preset;
use crate::data::partition::{label_coverage, PartitionScheme, Partitioner};
use crate::forecast::evaluate_series;
use crate::learners::{HardwareScenario, ProfilePool};
use crate::runtime::builtin_variant;
use crate::trace::generator::session_cdf_checkpoints;
use crate::trace::{TraceConfig, TraceSet, DAY};
use crate::util::stats;

/// Fig. 5: illustrative 4-round trace — how Oort vs RELAY pick 9 learners.
pub fn fig5(_opts: &FigureOpts) -> Result<()> {
    println!("--- Fig. 5: illustrative selection trace (9 learners, 4 rounds) ---");
    // learner -> availability windows (seconds), speeds (task secs)
    let windows: [(usize, (f64, f64)); 9] = [
        (0, (0.0, 400.0)),
        (1, (0.0, 400.0)),
        (2, (0.0, 120.0)),   // limited availability
        (3, (50.0, 200.0)),  // limited availability
        (4, (0.0, 400.0)),
        (5, (0.0, 400.0)),
        (6, (150.0, 400.0)),
        (7, (0.0, 400.0)),
        (8, (0.0, 400.0)),
    ];
    let speeds = [30.0, 35.0, 90.0, 80.0, 40.0, 95.0, 45.0, 50.0, 110.0];
    let round_len = 100.0;
    println!("  availability (#=available):");
    for (id, (a, b)) in windows {
        let mut bar = String::new();
        for slot in 0..40 {
            let t = slot as f64 * 10.0;
            bar.push(if t >= a && t < b { '#' } else { '.' });
        }
        println!("   L{id} |{bar}| task={}s", speeds[id]);
    }
    for (name, least_avail_first) in [("Oort (fast-first)", false), ("RELAY (least-available-first)", true)] {
        println!("  {name}:");
        for round in 0..4 {
            let t0 = round as f64 * round_len;
            let mut cands: Vec<usize> = windows
                .iter()
                .filter(|(id, (a, b))| t0 >= *a && t0 < *b && speeds[*id] > 0.0)
                .map(|(id, _)| *id)
                .collect();
            if least_avail_first {
                // remaining availability ascending
                cands.sort_by(|&x, &y| {
                    let rx = windows[x].1 .1 - t0;
                    let ry = windows[y].1 .1 - t0;
                    rx.total_cmp(&ry)
                });
            } else {
                cands.sort_by(|&x, &y| speeds[x].total_cmp(&speeds[y]));
            }
            let picked: Vec<String> = cands.iter().take(3).map(|i| format!("L{i}")).collect();
            let stale: Vec<String> = cands
                .iter()
                .take(3)
                .filter(|&&i| speeds[i] > round_len)
                .map(|i| format!("L{i}(stale)"))
                .collect();
            println!(
                "   round {round}: picks {}  {}",
                picked.join(","),
                if least_avail_first && !stale.is_empty() {
                    format!("accepts {}", stale.join(","))
                } else if !least_avail_first && !stale.is_empty() {
                    format!("discards {}", stale.join(","))
                } else {
                    String::new()
                }
            );
        }
    }
    println!("  [paper: Oort misses limited-availability learners (L2, L3); RELAY reaches them and keeps straggler updates]");
    Ok(())
}

/// Fig. 13: device heterogeneity CDF + 6-cluster decomposition.
pub fn fig13(opts: &FigureOpts) -> Result<()> {
    println!("--- Fig. 13: learner computational heterogeneity ---");
    let n = opts.scaled(4000, 500);
    let pool = ProfilePool::generate(n, 13, HardwareScenario::Hs1);
    let points = [0.03, 0.05, 0.1, 0.2, 0.4, 0.8, 1.6, 3.0];
    let cdf = pool.speed_cdf(&points);
    println!("  (a) CDF of per-sample train time:");
    for (p, c) in points.iter().zip(&cdf) {
        println!("      <= {:>5.2}s : {:>5.1}%", p, 100.0 * c);
    }
    let (centroids, pops) = pool.speed_clusters(7);
    println!("  (b) 6 device clusters (centroid sec/sample : population):");
    for (i, (c, p)) in centroids.iter().zip(&pops).enumerate() {
        println!("      cluster {} : {:>5.2}s : {:>5} devices ({:.0}%)",
            i, c, p, 100.0 * *p as f64 / n as f64);
    }
    println!("  [paper: long-tail speeds, ~20x spread, 6 distinguishable clusters]");
    Ok(())
}

/// Fig. 14: availability diurnal pattern + session-length CDF.
pub fn fig14(opts: &FigureOpts) -> Result<()> {
    println!("--- Fig. 14: learner availability dynamics ---");
    let n = opts.scaled(2000, 300);
    let trace = TraceSet::generate(n, 14, TraceConfig::default());
    let timeline = trace.availability_timeline(3600.0);
    println!("  (a) available learners per hour (first 2 days):");
    for day in 0..2 {
        let row: Vec<String> = (0..24)
            .map(|h| format!("{:>4}", timeline[day * 24 + h]))
            .collect();
        println!("      day {}: {}", day, row.join(""));
    }
    let lens = trace.session_lengths();
    println!("  (b) session-length CDF:");
    for (secs, frac) in session_cdf_checkpoints(&trace) {
        println!("      <= {:>6.0}s ({:>4.0} min): {:>5.1}%", secs, secs / 60.0, 100.0 * frac);
    }
    let p50 = stats::percentile(&lens, 50.0);
    println!("      median session: {:.0}s ({:.1} min)", p50, p50 / 60.0);
    println!("  [paper: diurnal cycle; ~70% of sessions < 10 min; long tail]");
    Ok(())
}

/// Fig. 21: label-frequency coverage under the FedScale mapping.
pub fn fig21(opts: &FigureOpts) -> Result<()> {
    println!("--- Fig. 21: label repetitions across learners (FedScale mapping) ---");
    let v = builtin_variant("speech");
    let n = opts.scaled(3000, 300);
    let shards = Partitioner::new(PartitionScheme::FedScale, v.num_classes, 100).assign(n, 21);
    let cov = label_coverage(&shards, v.num_classes);
    let min = cov.iter().cloned().fold(1.0, f64::min);
    let mean = stats::mean(&cov);
    println!("  labels: {}   learners: {}", v.num_classes, n);
    println!("  per-label learner coverage: min {:.0}%, mean {:.0}%", 100.0 * min, 100.0 * mean);
    let over40 = cov.iter().filter(|&&c| c >= 0.4).count();
    println!("  labels appearing on >=40% of learners: {}/{}", over40, v.num_classes);
    println!("  [paper E.1: all labels on >=40% of learners -> FedScale map is near-IID]");
    Ok(())
}

/// Table 1: benchmark presets (our scaled stand-ins).
pub fn table1(_opts: &FigureOpts) -> Result<()> {
    println!("--- Table 1: benchmark summary (scaled stand-ins, DESIGN.md 2) ---");
    println!(
        "  {:<11} {:>8} {:>6} {:>8} {:>7} {:>7} {:>7} {:>8}",
        "benchmark", "params", "dim", "classes", "batch", "lr", "epochs", "server"
    );
    for b in ["speech", "cifar", "openimage", "nlp"] {
        let c = preset(b)?;
        let v = builtin_variant(&c.variant);
        println!(
            "  {:<11} {:>8} {:>6} {:>8} {:>7} {:>7} {:>7} {:>8}",
            b, v.num_params, v.input_dim, v.num_classes, v.batch, c.lr, c.local_epochs, c.server_opt
        );
    }
    Ok(())
}

/// §5.2 forecast-quality experiment: Prophet-substitute on per-device
/// charging series (train first 50%, predict the rest).
pub fn forecast_eval(opts: &FigureOpts) -> Result<()> {
    println!("--- 5.2: learner availability prediction model ---");
    let devices = opts.scaled(137, 60).min(137); // paper: 137 Stunner devices
    // The paper filters the Stunner trace to devices with >= 1000 samples —
    // i.e. the heavily-observed, regular chargers; generate that population.
    let trace = TraceSet::generate(devices, 52, TraceConfig::regular());
    let step = 900.0; // 15-minute sampling
    let mut r2s = Vec::new();
    let mut mses = Vec::new();
    let mut maes = Vec::new();
    for d in 0..devices {
        // 4 replayed weeks (the trace wraps) = "at least 1000 samples"
        let week = trace.sample_series(d, step);
        let mut series = Vec::with_capacity(week.len() * 4);
        for _ in 0..4 {
            series.extend_from_slice(&week);
        }
        let times: Vec<f64> = (0..series.len()).map(|i| i as f64 * step).collect();
        let (r2, mse, mae) = evaluate_series(&times, &series);
        r2s.push(r2);
        mses.push(mse);
        maes.push(mae);
    }
    println!("  devices evaluated: {devices} (series of {} samples @ 15 min)", 4 * (7.0 * DAY / step) as usize);
    println!(
        "  mean R^2 = {:.3}   mean MSE = {:.4}   mean MAE = {:.4}",
        stats::mean(&r2s),
        stats::mean(&mses),
        stats::mean(&maes)
    );
    println!("  [paper: R^2 0.93, MSE 0.01, MAE 0.028 — periodic charging is highly predictable]");
    Ok(())
}
