//! Ablations over RELAY's design choices (DESIGN.md §5): the knobs the
//! paper fixes by fiat get swept here so their sensitivity is documented.
//!
//! * `beta`      — Eq. 2's staleness-vs-deviation mix (paper: 0.35)
//! * `threshold` — staleness bound (paper: none for RELAY, 5 for SAFA)
//! * `cooldown`  — post-participation hold-out rounds (paper: 5)
//! * `overcommit`— OC factor (paper: 1.3)
//! * `alpha`     — APT's round-duration EMA (paper: 0.25)
//! * `buffer`    — async-regime merge buffer size K (FedBuff-style cells)
//! * `staleness-bound` — async-regime max staleness in model versions

use anyhow::{anyhow, Result};

use super::configs::speech;
use super::runner::{print_resource_table, run_set, FigureOpts};
use crate::aggregation::scaling::ScalingRule;
use crate::config::{AvailMode, ExpConfig, RoundMode};
use crate::data::partition::{LabelSkew, PartitionScheme};

fn base(opts: &FigureOpts) -> ExpConfig {
    let mut c = speech(opts).relay();
    c.avail = AvailMode::DynAvail;
    c.partition = PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Uniform };
    c.mode = RoundMode::Deadline { deadline: 100.0 };
    c
}

pub fn run(name: &str, opts: &FigureOpts) -> Result<()> {
    let configs: Vec<ExpConfig> = match name {
        "beta" => [0.0, 0.35, 0.7, 1.0]
            .iter()
            .map(|&beta| {
                let mut c = base(opts);
                c.scaling = ScalingRule::Relay { beta };
                c.with_label(format!("beta={beta}"))
            })
            .collect(),
        "threshold" => [Some(1), Some(5), Some(20), None]
            .iter()
            .map(|&th| {
                let mut c = base(opts);
                c.staleness_threshold = th;
                c.with_label(match th {
                    Some(t) => format!("threshold={t}"),
                    None => "threshold=none".into(),
                })
            })
            .collect(),
        "cooldown" => [0usize, 2, 5, 10]
            .iter()
            .map(|&cd| {
                let mut c = base(opts);
                c.cooldown_rounds = cd;
                c.with_label(format!("cooldown={cd}"))
            })
            .collect(),
        "overcommit" => [1.0, 1.3, 1.6, 2.0]
            .iter()
            .map(|&f| {
                let mut c = base(opts);
                c.mode = RoundMode::OverCommit { factor: f };
                c.with_label(format!("overcommit={f}"))
            })
            .collect(),
        "alpha" => [0.1, 0.25, 0.5, 0.9]
            .iter()
            .map(|&a| {
                let mut c = base(opts);
                c.apt_alpha = a;
                c.with_label(format!("apt-alpha={a}"))
            })
            .collect(),
        "buffer" => [2usize, 5, 10, 20]
            .iter()
            .map(|&k| {
                let mut c = base(opts);
                c.mode = RoundMode::Async { buffer_k: k, max_staleness: Some(10) };
                c.with_label(format!("buffer={k}"))
            })
            .collect(),
        "staleness-bound" => [Some(1usize), Some(5), Some(20), None]
            .iter()
            .map(|&th| {
                let mut c = base(opts);
                c.mode = RoundMode::Async { buffer_k: 10, max_staleness: th };
                c.with_label(match th {
                    Some(t) => format!("staleness-bound={t}"),
                    None => "staleness-bound=none".into(),
                })
            })
            .collect(),
        other => {
            return Err(anyhow!(
                "unknown ablation '{other}' (beta|threshold|cooldown|overcommit|alpha|buffer|staleness-bound|all)"
            ))
        }
    };
    let results = run_set(
        &format!("ablation_{name}"),
        &format!("Ablation: {name} (RELAY, DL+DynAvail, label-uniform)"),
        configs,
        opts,
    )?;
    print_resource_table(&results);
    Ok(())
}

pub fn run_all(opts: &FigureOpts) -> Result<()> {
    for name in [
        "beta",
        "threshold",
        "cooldown",
        "overcommit",
        "alpha",
        "buffer",
        "staleness-bound",
    ] {
        run(name, opts)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_ablation_errors() {
        let opts = FigureOpts::default();
        assert!(run("bogus", &opts).is_err());
    }

    #[test]
    fn async_ablation_configs_validate() {
        // the relay base sets apt=true; async mode must still validate
        // (APT is defined as ignored there, not rejected)
        let opts = FigureOpts::default();
        let mut c = base(&opts);
        c.mode = RoundMode::Async { buffer_k: 5, max_staleness: Some(10) };
        c.validate().unwrap();
    }

    #[test]
    fn beta_sweep_builds_valid_configs() {
        // construct-only check (running uses the figure harness)
        let opts = FigureOpts::default();
        let c = base(&opts);
        c.validate().unwrap();
        assert_eq!(c.selector, "priority");
        assert!(c.use_saa);
    }
}
