//! Shared figure-harness machinery: run a set of configs (optionally over
//! several seeds), print the paper-style comparison rows, persist series.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::ExpConfig;
use crate::metrics::ExperimentResult;
use crate::runtime::{self, Backend, Executor};
use crate::util::json::{arr, obj, Json};

#[derive(Clone, Debug)]
pub struct FigureOpts {
    pub artifacts_dir: String,
    pub backend: Backend,
    /// Population/round scale factor (1.0 = paper scale). Defaults < 1 keep
    /// the whole suite tractable on a CPU testbed.
    pub scale: f64,
    pub out_dir: String,
    pub seeds: usize,
    pub verbose: bool,
    /// Concurrent experiments on the sweep engine (0 = one per core, capped
    /// at 8). Results are identical at any setting. Defaults to 1: the full
    /// figure campaign at 8x working sets has OOMed a 35 GB box before, so
    /// concurrency here is opt-in (`--workers N`).
    pub workers: usize,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts {
            artifacts_dir: "artifacts".into(),
            backend: Backend::Pjrt,
            scale: 0.3,
            out_dir: "results".into(),
            seeds: 1,
            verbose: false,
            workers: 1,
        }
    }
}

impl FigureOpts {
    /// Scale a paper-sized count down (never below `min`).
    pub fn scaled(&self, paper: usize, min: usize) -> usize {
        ((paper as f64 * self.scale).round() as usize).max(min)
    }

    pub fn executor(&self, variant: &str) -> Result<Arc<dyn Executor>> {
        match self.backend {
            Backend::Pjrt => runtime::load_executor(&self.artifacts_dir, variant, Backend::Pjrt)
                .with_context(|| {
                    format!("loading {variant} artifacts (run `make artifacts`, or --backend native)")
                }),
            Backend::Native => Ok(Arc::new(runtime::NativeExecutor::new(
                runtime::builtin_variant(variant),
            ))),
        }
    }
}

/// Run each config (averaging over `opts.seeds` seeds), print summaries,
/// save the full series to `<out_dir>/<name>.json`, and return results.
///
/// Execution goes through the sweep engine (`sweep::run_many`): all
/// config×seed runs of the set proceed concurrently, and since results come
/// back in input order the per-config grouping below — and therefore every
/// figure — is identical at any worker count.
pub fn run_set(
    name: &str,
    title: &str,
    configs: Vec<ExpConfig>,
    opts: &FigureOpts,
) -> Result<Vec<ExperimentResult>> {
    println!("--- {title} ---");
    // One executor (one PJRT client) per variant for the whole set: each
    // TfrtCpuClient owns arenas/thread pools that are expensive to multiply
    // (a fresh client per config OOMed the full campaign on a 35 GB box).
    let mut executors: std::collections::BTreeMap<String, Arc<dyn Executor>> =
        std::collections::BTreeMap::new();
    let seeds = opts.seeds.max(1);
    let mut runs = Vec::with_capacity(configs.len() * seeds);
    for cfg in &configs {
        let exec = match executors.get(&cfg.variant) {
            Some(e) => Arc::clone(e),
            None => {
                let e = opts.executor(&cfg.variant)?;
                executors.insert(cfg.variant.clone(), Arc::clone(&e));
                e
            }
        };
        for s in 0..seeds {
            let mut c = cfg.clone();
            c.seed = cfg.seed + s as u64 * 1000;
            runs.push((c, Arc::clone(&exec)));
        }
    }
    let results = crate::sweep::run_many(runs, opts.workers, opts.verbose)?;
    let mut all = Vec::with_capacity(configs.len());
    for i in 0..configs.len() {
        let group = results[i * seeds..(i + 1) * seeds].to_vec();
        let merged = average_results(group);
        println!("  {}", merged.summary());
        all.push(merged);
    }
    save(name, &all, opts)?;
    Ok(all)
}

/// Average per-round metrics across seeds (the paper reports 3-seed means).
pub fn average_results(mut results: Vec<ExperimentResult>) -> ExperimentResult {
    if results.len() == 1 {
        return results.pop().unwrap();
    }
    let mut base = results[0].clone();
    for rec in base.rounds.iter_mut() {
        let idx = rec.round;
        let mut res_sum = 0.0;
        let mut res_n = 0.0;
        let mut acc_sum = 0.0;
        let mut acc_n = 0.0;
        for r in &results {
            if let Some(other) = r.rounds.iter().find(|x| x.round == idx) {
                res_sum += other.cum_resource_secs;
                res_n += 1.0;
                if let Some(a) = other.test_accuracy {
                    acc_sum += a;
                    acc_n += 1.0;
                }
            }
        }
        if res_n > 0.0 {
            rec.cum_resource_secs = res_sum / res_n;
        }
        if acc_n > 0.0 {
            rec.test_accuracy = Some(acc_sum / acc_n);
        }
    }
    base
}

pub fn save(name: &str, results: &[ExperimentResult], opts: &FigureOpts) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = PathBuf::from(&opts.out_dir).join(format!("{name}.json"));
    let j = obj(vec![
        ("figure", Json::Str(name.into())),
        ("scale", crate::util::json::num(opts.scale)),
        ("series", arr(results.iter().map(|r| r.to_json()))),
    ]);
    std::fs::write(&path, j.to_string()).with_context(|| format!("writing {path:?}"))?;
    println!("  -> series saved to {}", path.display());
    Ok(())
}

/// Print the paper-style "accuracy vs resources" checkpoints for a set.
pub fn print_resource_table(results: &[ExperimentResult]) {
    println!(
        "  {:<28} {:>10} {:>10} {:>10} {:>8}",
        "config", "res(h)", "time(s)", "waste%", "final acc"
    );
    for r in results {
        println!(
            "  {:<28} {:>10.2} {:>10.0} {:>9.1}% {:>7.1}%",
            r.label,
            r.final_resource_hours(),
            r.final_sim_time(),
            100.0 * r.waste_fraction(),
            100.0 * r.final_accuracy().unwrap_or(f64::NAN)
        );
    }
}

/// Print accuracy trajectories at shared resource checkpoints.
pub fn print_series(results: &[ExperimentResult], points: usize) {
    for r in results {
        let series = r.accuracy_vs_resources();
        if series.is_empty() {
            continue;
        }
        let step = (series.len() / points.max(1)).max(1);
        let line: Vec<String> = series
            .iter()
            .step_by(step)
            .map(|(res, acc)| format!("{:.2}h:{:.0}%", res, acc * 100.0))
            .collect();
        println!("  {:<28} {}", r.label, line.join("  "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;

    #[test]
    fn scaled_respects_min() {
        let opts = FigureOpts { scale: 0.1, ..Default::default() };
        assert_eq!(opts.scaled(1000, 50), 100);
        assert_eq!(opts.scaled(100, 50), 50);
    }

    #[test]
    fn average_merges_accuracy() {
        let mk = |acc: f64| ExperimentResult {
            label: "x".into(),
            rounds: vec![RoundRecord {
                round: 0,
                test_accuracy: Some(acc),
                cum_resource_secs: 100.0,
                ..Default::default()
            }],
            perplexity_metric: false,
        };
        let merged = average_results(vec![mk(0.4), mk(0.6)]);
        assert!((merged.rounds[0].test_accuracy.unwrap() - 0.5).abs() < 1e-12);
        assert!((merged.rounds[0].cum_resource_secs - 100.0).abs() < 1e-9);
    }

    #[test]
    fn single_result_passthrough() {
        let r = ExperimentResult { label: "solo".into(), ..Default::default() };
        assert_eq!(average_results(vec![r]).label, "solo");
    }
}
