//! Runtime layer: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`, produced once by `make artifacts`) and executes them on
//! the PJRT CPU client from the coordinator's round path. Also provides a
//! pure-rust [`native::NativeExecutor`] mirror used as fallback/cross-check.

// the model-math hot path: a stray unwrap here panics mid-round, so force
// every failure through Result (or an expect that documents the invariant)
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod executor;
pub mod manifest;
pub mod native;

use std::sync::Arc;

use anyhow::Result;

pub use executor::{Executor, PjrtExecutor, TrainOut};
pub use manifest::{Manifest, VariantInfo};
pub use native::NativeExecutor;

/// Which model-math implementation to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT HLO on the XLA CPU PJRT client (the production path).
    Pjrt,
    /// Pure-rust mirror (fallback when artifacts are absent; cross-check).
    Native,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "pjrt" => Some(Backend::Pjrt),
            "native" => Some(Backend::Native),
            _ => None,
        }
    }
}

/// Load an executor for `variant` from the artifacts directory.
pub fn load_executor(
    artifacts_dir: &str,
    variant: &str,
    backend: Backend,
) -> Result<Arc<dyn Executor>> {
    let manifest = Manifest::load(artifacts_dir)?;
    match backend {
        Backend::Pjrt => Ok(Arc::new(PjrtExecutor::load(&manifest, variant)?)),
        Backend::Native => Ok(Arc::new(NativeExecutor::new(manifest.variant(variant)?.clone()))),
    }
}

/// Like [`load_executor`] but falls back to the native mirror (with the
/// built-in variant table) when artifacts are missing. Used by tests and the
/// quickstart example so `cargo test` works before `make artifacts`.
pub fn load_executor_or_native(artifacts_dir: &str, variant: &str) -> Arc<dyn Executor> {
    if let Ok(m) = Manifest::load(artifacts_dir) {
        if let Ok(e) = PjrtExecutor::load(&m, variant) {
            return Arc::new(e);
        }
    }
    Arc::new(NativeExecutor::new(builtin_variant(variant)))
}

/// Built-in copy of the variant table (mirrors `model.py::VARIANTS`); keeps
/// the native backend usable without artifacts. `manifest.rs` tests assert
/// the two stay in sync when artifacts are present.
pub fn builtin_variant(name: &str) -> VariantInfo {
    let (input_dim, num_classes, hidden, batch, max_updates, perplexity) = match name {
        "tiny" => (16, 4, vec![8], 4, 8, false),
        "speech" => (256, 35, vec![128, 64], 20, 32, false),
        "cifar" => (256, 10, vec![128, 64], 10, 32, false),
        "openimage" => (256, 60, vec![128, 64], 30, 32, false),
        "nlp" => (128, 64, vec![128], 40, 32, true),
        other => panic!("unknown builtin variant '{other}'"),
    };
    let mut dims = vec![input_dim];
    dims.extend(&hidden);
    dims.push(num_classes);
    let num_params = (0..dims.len() - 1).map(|i| dims[i] * dims[i + 1] + dims[i + 1]).sum();
    VariantInfo {
        name: name.to_string(),
        num_params,
        input_dim,
        num_classes,
        hidden,
        batch,
        max_updates,
        perplexity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_variants_param_counts() {
        assert_eq!(builtin_variant("tiny").num_params, 172);
        let v = builtin_variant("speech");
        assert_eq!(v.num_params, 256 * 128 + 128 + 128 * 64 + 64 + 64 * 35 + 35);
    }

    #[test]
    #[should_panic(expected = "unknown builtin variant")]
    fn unknown_builtin_panics() {
        builtin_variant("nope");
    }

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("pjrt"), Some(Backend::Pjrt));
        assert_eq!(Backend::parse("native"), Some(Backend::Native));
        assert_eq!(Backend::parse("x"), None);
    }
}
