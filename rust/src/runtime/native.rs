//! Pure-rust mirror of the L2 model semantics (`python/compile/model.py`).
//!
//! Two purposes: (1) cross-check of the AOT path — integration tests compare
//! it bit-for-bit-ish (f32 tolerance) against `PjrtExecutor`; (2) fallback
//! backend so the simulator runs in environments where `make artifacts`
//! hasn't been run (e.g. plain `cargo test`).
//!
//! The only intentional divergence is `init_params`: jax's threefry stream is
//! not reproduced, so native init draws from our xoshiro RNG with the same
//! He scaling. Given identical inputs, train/eval/agg match the HLO path.

use anyhow::{anyhow, Result};

use super::executor::{Executor, TrainOut};
use super::manifest::VariantInfo;
use crate::util::rng::Rng;

pub struct NativeExecutor {
    info: VariantInfo,
}

impl NativeExecutor {
    pub fn new(info: VariantInfo) -> Self {
        NativeExecutor { info }
    }

    /// Forward pass; returns per-layer pre-activations z and activations h
    /// (h[0] = input), for use by backward.
    fn forward(&self, params: &[f32], x: &[f32]) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let v = &self.info;
        let b = v.batch;
        let shapes = v.layer_shapes();
        let mut hs: Vec<Vec<f32>> = vec![x.to_vec()];
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(shapes.len());
        let mut off = 0usize;
        for (li, &(di, do_)) in shapes.iter().enumerate() {
            let w = &params[off..off + di * do_];
            off += di * do_;
            let bias = &params[off..off + do_];
            off += do_;
            let h = hs.last().expect("hs starts with the input activation");
            let mut z = vec![0f32; b * do_];
            matmul_acc(h, w, &mut z, b, di, do_);
            for r in 0..b {
                for c in 0..do_ {
                    z[r * do_ + c] += bias[c];
                }
            }
            let last = li + 1 == shapes.len();
            let hnext = if last {
                z.clone()
            } else {
                z.iter().map(|&v| v.max(0.0)).collect()
            };
            zs.push(z);
            hs.push(hnext);
        }
        (zs, hs)
    }

    /// Per-row log-softmax probabilities + nll + argmax for the logits.
    fn softmax_stats(&self, logits: &[f32], y: &[i32]) -> (Vec<f32>, Vec<f32>, Vec<usize>) {
        let v = &self.info;
        let (b, c) = (v.batch, v.num_classes);
        let mut probs = vec![0f32; b * c];
        let mut nll = vec![0f32; b];
        let mut argmax = vec![0usize; b];
        for r in 0..b {
            let row = &logits[r * c..(r + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f64;
            for &l in row {
                denom += ((l - m) as f64).exp();
            }
            let log_denom = denom.ln() as f32;
            let mut best = 0usize;
            for j in 0..c {
                let logp = row[j] - m - log_denom;
                probs[r * c + j] = logp.exp();
                if row[j] > row[best] {
                    best = j;
                }
            }
            argmax[r] = best;
            nll[r] = -(row[y[r] as usize] - m - log_denom);
        }
        (probs, nll, argmax)
    }
}

/// Tile edge (f32 elements) for the blocked kernels below — the same block
/// shape the Pallas grid uses in `python/compile/kernels/matmul.py` (one
/// (i, j) output tile per program, revisited across the kk grid axis), sized
/// so an output tile plus its operand strips stay L1-resident.
const TILE: usize = 64;

/// out[b][n] += x[b][k] * w[k][n] — row-major, f32 accumulate (matches the
/// Pallas kernel's preferred_element_type=f32).
///
/// Blocked over output columns, mirroring the Pallas (i, j, kk) grid: each
/// j-tile of an output row is revisited across the full ascending-k strip.
/// **Bitwise-stable**: for every output element the adds happen in the same
/// ascending-k order, with the same `xv == 0.0` skip set, as the retained
/// scalar reference — pinned bit-for-bit by the tests below.
fn matmul_acc(x: &[f32], w: &[f32], out: &mut [f32], b: usize, k: usize, n: usize) {
    for r in 0..b {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for (jb, otile) in orow.chunks_mut(TILE).enumerate() {
            let j0 = jb * TILE;
            let jw = otile.len();
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wtile = &w[kk * n + j0..kk * n + j0 + jw];
                for (o, &wv) in otile.iter_mut().zip(wtile) {
                    *o += xv * wv;
                }
            }
        }
    }
}

/// out[k][n] += x^T[k][b] * g[b][n] for dW.
///
/// Blocked over (k, n) output tiles; the batch (reduction) axis stays the
/// outermost loop *inside* each tile, so every output element accumulates
/// in the same ascending-r order (and `xv == 0.0` skip set) as the scalar
/// reference — bitwise-identical at any tile size.
fn matmul_at_b(x: &[f32], g: &[f32], out: &mut [f32], b: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(TILE) {
        let k1 = (k0 + TILE).min(k);
        for j0 in (0..n).step_by(TILE) {
            let j1 = (j0 + TILE).min(n);
            for r in 0..b {
                let xrow = &x[r * k..(r + 1) * k];
                let grow = &g[r * n + j0..r * n + j1];
                for kk in k0..k1 {
                    let xv = xrow[kk];
                    if xv == 0.0 {
                        continue;
                    }
                    let otile = &mut out[kk * n + j0..kk * n + j1];
                    for (o, &gv) in otile.iter_mut().zip(grow) {
                        *o += xv * gv;
                    }
                }
            }
        }
    }
}

/// out[b][k] += g[b][n] * w^T[n][k] for dh.
///
/// Blocked over w row-strips (reused across the whole batch while hot).
/// The n (reduction) loop is deliberately **not** tiled: each output element
/// is one local f32 accumulator chain over ascending j, and splitting it
/// would change the rounding — the chain is kept whole so the result stays
/// bitwise-identical to the scalar reference.
fn matmul_b_wt(g: &[f32], w: &[f32], out: &mut [f32], b: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(TILE) {
        let k1 = (k0 + TILE).min(k);
        for r in 0..b {
            let grow = &g[r * n..(r + 1) * n];
            let orow = &mut out[r * k..(r + 1) * k];
            for kk in k0..k1 {
                let wrow = &w[kk * n..(kk + 1) * n];
                let mut acc = 0f32;
                for (&gv, &wv) in grow.iter().zip(wrow) {
                    acc += gv * wv;
                }
                orow[kk] += acc;
            }
        }
    }
}

/// Retained scalar reference for [`matmul_acc`] — the pre-tiling kernel,
/// kept verbatim as the bitwise oracle for the property tests.
#[cfg(test)]
fn matmul_acc_scalar(x: &[f32], w: &[f32], out: &mut [f32], b: usize, k: usize, n: usize) {
    // i-k-j loop order: streams w rows, vectorizes the inner j loop.
    for r in 0..b {
        let xrow = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

/// Retained scalar reference for [`matmul_at_b`] (bitwise oracle).
#[cfg(test)]
fn matmul_at_b_scalar(x: &[f32], g: &[f32], out: &mut [f32], b: usize, k: usize, n: usize) {
    for r in 0..b {
        let xrow = &x[r * k..(r + 1) * k];
        let grow = &g[r * n..(r + 1) * n];
        for kk in 0..k {
            let xv = xrow[kk];
            if xv == 0.0 {
                continue;
            }
            let orow = &mut out[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += xv * grow[j];
            }
        }
    }
}

/// Retained scalar reference for [`matmul_b_wt`] (bitwise oracle).
#[cfg(test)]
fn matmul_b_wt_scalar(g: &[f32], w: &[f32], out: &mut [f32], b: usize, k: usize, n: usize) {
    for r in 0..b {
        let grow = &g[r * n..(r + 1) * n];
        let orow = &mut out[r * k..(r + 1) * k];
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0f32;
            for j in 0..n {
                acc += grow[j] * wrow[j];
            }
            orow[kk] += acc;
        }
    }
}

impl Executor for NativeExecutor {
    fn variant(&self) -> &VariantInfo {
        &self.info
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let mut rng = Rng::new(seed as u64 ^ 0x52454C41595F4E41); // "RELAY_NA"
        let mut out = Vec::with_capacity(self.info.num_params);
        for (di, do_) in self.info.layer_shapes() {
            let scale = (2.0 / di as f64).sqrt();
            for _ in 0..di * do_ {
                out.push((rng.normal() * scale) as f32);
            }
            out.extend(std::iter::repeat(0f32).take(do_)); // biases zero
        }
        Ok(out)
    }

    fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> Result<TrainOut> {
        let v = &self.info;
        if params.len() != v.num_params {
            return Err(anyhow!("params len {} != P={}", params.len(), v.num_params));
        }
        let b = v.batch;
        let shapes = v.layer_shapes();
        let (zs, hs) = self.forward(params, x);
        let logits = hs.last().expect("forward always pushes the logits");
        let (probs, nll, argmax) = self.softmax_stats(logits, y);

        let denom: f32 = mask.iter().sum::<f32>().max(1.0);
        let loss: f32 = nll.iter().zip(mask).map(|(l, m)| l * m).sum::<f32>() / denom;
        let correct: f32 = argmax
            .iter()
            .zip(y)
            .zip(mask)
            .map(|((a, yy), m)| if *a == *yy as usize { *m } else { 0.0 })
            .sum();

        // Backward. dz for the head: mask*(p - onehot)/denom.
        let c = v.num_classes;
        let mut dz = vec![0f32; b * c];
        for r in 0..b {
            for j in 0..c {
                let one = if j == y[r] as usize { 1.0 } else { 0.0 };
                dz[r * c + j] = mask[r] * (probs[r * c + j] - one) / denom;
            }
        }

        let mut new_params = params.to_vec();
        // Walk layers backwards; track param offsets.
        let mut offsets = Vec::with_capacity(shapes.len());
        let mut off = 0usize;
        for &(di, do_) in &shapes {
            offsets.push(off);
            off += di * do_ + do_;
        }
        for li in (0..shapes.len()).rev() {
            let (di, do_) = shapes[li];
            let off = offsets[li];
            let h_prev = &hs[li];
            // dW = h_prev^T dz ; db = colsum dz
            let mut dw = vec![0f32; di * do_];
            matmul_at_b(h_prev, &dz, &mut dw, b, di, do_);
            for (i, g) in dw.iter().enumerate() {
                new_params[off + i] -= lr * g;
            }
            for j in 0..do_ {
                let mut db = 0f32;
                for r in 0..b {
                    db += dz[r * do_ + j];
                }
                new_params[off + di * do_ + j] -= lr * db;
            }
            if li > 0 {
                // dh_prev = dz W^T, gated by relu'(z_{l-1})
                let w = &params[off..off + di * do_];
                let mut dh = vec![0f32; b * di];
                matmul_b_wt(&dz, w, &mut dh, b, di, do_);
                let zprev = &zs[li - 1];
                for i in 0..b * di {
                    if zprev[i] <= 0.0 {
                        dh[i] = 0.0;
                    }
                }
                dz = dh;
            }
        }
        Ok(TrainOut { params: new_params, loss, correct })
    }

    fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32], mask: &[f32]) -> Result<(f32, f32)> {
        let (_, hs) = self.forward(params, x);
        let logits = hs.last().expect("forward always pushes the logits");
        let (_, nll, argmax) = self.softmax_stats(logits, y);
        let sum_loss: f32 = nll.iter().zip(mask).map(|(l, m)| l * m).sum();
        let correct: f32 = argmax
            .iter()
            .zip(y)
            .zip(mask)
            .map(|((a, yy), m)| if *a == *yy as usize { *m } else { 0.0 })
            .sum();
        Ok((sum_loss, correct))
    }

    fn agg_combine(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        let p = self.info.num_params;
        if updates.len() != weights.len() {
            return Err(anyhow!("updates/weights length mismatch"));
        }
        let mut out = vec![0f32; p];
        for (row, &w) in updates.iter().zip(weights) {
            if row.len() != p {
                return Err(anyhow!("update row len {} != P={p}", row.len()));
            }
            for i in 0..p {
                out[i] += w * row[i];
            }
        }
        Ok(out)
    }

    fn agg_dev(&self, fresh: &[f32], stale: &[&[f32]]) -> Result<Vec<f32>> {
        let p = self.info.num_params;
        if fresh.len() != p {
            return Err(anyhow!("fresh len {} != P={p}", fresh.len()));
        }
        let mut out = Vec::with_capacity(stale.len() + 1);
        for row in stale {
            let mut d = 0f64;
            for i in 0..p {
                let diff = (fresh[i] - row[i]) as f64;
                d += diff * diff;
            }
            out.push(d as f32);
        }
        let fnorm: f64 = fresh.iter().map(|&f| (f as f64) * (f as f64)).sum();
        out.push(fnorm as f32);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> VariantInfo {
        VariantInfo {
            name: "tiny".into(),
            num_params: 172,
            input_dim: 16,
            num_classes: 4,
            hidden: vec![8],
            batch: 4,
            max_updates: 8,
            perplexity: false,
        }
    }

    fn batch(v: &VariantInfo, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..v.batch * v.input_dim).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..v.batch).map(|_| rng.below(v.num_classes) as i32).collect();
        (x, y, vec![1.0; v.batch])
    }

    #[test]
    fn init_len_and_determinism() {
        let e = NativeExecutor::new(tiny());
        let p = e.init_params(3).unwrap();
        assert_eq!(p.len(), 172);
        assert_eq!(p, e.init_params(3).unwrap());
        assert_ne!(p, e.init_params(4).unwrap());
    }

    #[test]
    fn training_descends() {
        let v = tiny();
        let e = NativeExecutor::new(v.clone());
        let mut p = e.init_params(0).unwrap();
        let (x, y, m) = batch(&v, 1);
        let first = e.train_step(&p, &x, &y, &m, 0.1).unwrap().loss;
        let mut last = first;
        for _ in 0..50 {
            let out = e.train_step(&p, &x, &y, &m, 0.1).unwrap();
            p = out.params;
            last = out.loss;
        }
        assert!(last < first * 0.5, "no descent: {first} -> {last}");
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let v = tiny();
        let e = NativeExecutor::new(v.clone());
        let p = e.init_params(7).unwrap();
        let (x, y, m) = batch(&v, 8);
        let lr = 1.0f32; // update = -grad exactly
        let out = e.train_step(&p, &x, &y, &m, lr).unwrap();
        let grad: Vec<f32> = p.iter().zip(&out.params).map(|(a, b)| a - b).collect();
        let loss_of = |pp: &[f32]| -> f32 {
            let (s, _) = e.eval_batch(pp, &x, &y, &m).unwrap();
            s / m.iter().sum::<f32>()
        };
        let eps = 1e-2f32;
        for idx in [0usize, 40, 100, 171] {
            let mut pp = p.clone();
            pp[idx] += eps;
            let up = loss_of(&pp);
            pp[idx] -= 2.0 * eps;
            let dn = loss_of(&pp);
            let num = (up - dn) / (2.0 * eps);
            assert!(
                (num - grad[idx]).abs() < 2e-2 + 0.1 * num.abs(),
                "idx {idx}: analytic {} vs numeric {num}",
                grad[idx]
            );
        }
    }

    #[test]
    fn mask_zeroes_row_influence() {
        let v = tiny();
        let e = NativeExecutor::new(v.clone());
        let p = e.init_params(9).unwrap();
        let (mut x, y, _) = batch(&v, 10);
        let mut mask = vec![1.0f32; v.batch];
        mask[v.batch - 1] = 0.0;
        let o1 = e.train_step(&p, &x, &y, &mask, 0.05).unwrap();
        for i in 0..v.input_dim {
            x[(v.batch - 1) * v.input_dim + i] = 1e3;
        }
        let o2 = e.train_step(&p, &x, &y, &mask, 0.05).unwrap();
        assert_eq!(o1.loss, o2.loss);
        assert_eq!(o1.params, o2.params);
    }

    /// Random matrix with exact zeros sprinkled in, exercising the kernels'
    /// `xv == 0.0` skip paths the way post-ReLU activations do.
    fn mat(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| if rng.bool(0.2) { 0.0 } else { rng.normal() as f32 })
            .collect()
    }

    fn assert_bits_eq(tiled: &[f32], scalar: &[f32], kernel: &str, dims: (usize, usize, usize)) {
        for (i, (t, s)) in tiled.iter().zip(scalar).enumerate() {
            assert_eq!(
                t.to_bits(),
                s.to_bits(),
                "{kernel} {dims:?}: element {i} diverged (tiled {t} vs scalar {s})"
            );
        }
    }

    #[test]
    fn tiled_matmuls_bitwise_equal_the_scalar_reference() {
        // ragged tail blocks, degenerate dims of 1, exact-TILE edges, and
        // sizes past one tile — every (b, k, n) must match bit-for-bit
        let interesting = [1usize, 2, 3, 5, 63, 64, 65, 100, 127, 128, 129];
        let mut rng = Rng::new(0x7E57_714E);
        let mut cases: Vec<(usize, usize, usize)> = Vec::new();
        for &b in &[1usize, 4, 20] {
            for &k in &interesting {
                for &n in &interesting {
                    cases.push((b, k, n));
                }
            }
        }
        for _ in 0..40 {
            cases.push((
                1 + rng.below(24),
                1 + rng.below(150),
                1 + rng.below(150),
            ));
        }
        for (b, k, n) in cases {
            let x = mat(&mut rng, b * k);
            let w = mat(&mut rng, k * n);
            let g = mat(&mut rng, b * n);
            // accumulate into a shared random base: += kernels must agree on
            // pre-existing content too, not just on zeroed outputs
            let base_bn = mat(&mut rng, b * n);
            let base_kn = mat(&mut rng, k * n);
            let base_bk = mat(&mut rng, b * k);

            let (mut t, mut s) = (base_bn.clone(), base_bn.clone());
            matmul_acc(&x, &w, &mut t, b, k, n);
            matmul_acc_scalar(&x, &w, &mut s, b, k, n);
            assert_bits_eq(&t, &s, "matmul_acc", (b, k, n));

            let (mut t, mut s) = (base_kn.clone(), base_kn.clone());
            matmul_at_b(&x, &g, &mut t, b, k, n);
            matmul_at_b_scalar(&x, &g, &mut s, b, k, n);
            assert_bits_eq(&t, &s, "matmul_at_b", (b, k, n));

            let (mut t, mut s) = (base_bk.clone(), base_bk.clone());
            matmul_b_wt(&g, &w, &mut t, b, k, n);
            matmul_b_wt_scalar(&g, &w, &mut s, b, k, n);
            assert_bits_eq(&t, &s, "matmul_b_wt", (b, k, n));
        }
    }

    #[test]
    fn agg_combine_weighted_sum() {
        let e = NativeExecutor::new(tiny());
        let a = vec![1.0f32; 172];
        let b = vec![2.0f32; 172];
        let out = e.agg_combine(&[&a, &b], &[0.25, 0.5]).unwrap();
        assert!(out.iter().all(|&v| (v - 1.25).abs() < 1e-6));
    }

    #[test]
    fn agg_dev_distances() {
        let e = NativeExecutor::new(tiny());
        let f = vec![1.0f32; 172];
        let s = vec![0.0f32; 172];
        let out = e.agg_dev(&f, &[&s]).unwrap();
        assert_eq!(out.len(), 2);
        assert!((out[0] - 172.0).abs() < 1e-3); // ||1-0||^2 per dim
        assert!((out[1] - 172.0).abs() < 1e-3); // ||f||^2
    }
}
