//! The `Executor` trait — the only surface through which the coordinator
//! touches model math — and its PJRT implementation, which loads the AOT
//! HLO-text artifacts and runs them on the XLA CPU client.
//!
//! Python is never on this path: artifacts are produced once by
//! `make artifacts` and the rust binary is self-contained afterwards.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{anyhow, Result};

use super::manifest::{Manifest, VariantInfo};

/// Output of one local SGD step.
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub params: Vec<f32>,
    pub loss: f32,
    pub correct: f32,
}

/// Model math surface used by the coordinator (L3). Implementations:
/// [`PjrtExecutor`] (AOT HLO on the XLA CPU client, the production path) and
/// [`super::native::NativeExecutor`] (pure-rust mirror, fallback/cross-check).
pub trait Executor: Send + Sync {
    fn variant(&self) -> &VariantInfo;

    /// Layer-scaled random init, deterministic per seed.
    fn init_params(&self, seed: i32) -> Result<Vec<f32>>;

    /// One masked-SGD step on a fixed-size batch.
    /// x: [B*D] row-major, y: [B] labels, mask: [B] 0/1, lr: step size.
    fn train_step(&self, params: &[f32], x: &[f32], y: &[i32], mask: &[f32], lr: f32)
        -> Result<TrainOut>;

    /// Returns (sum_loss, correct) over the masked batch.
    fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32], mask: &[f32])
        -> Result<(f32, f32)>;

    /// Weighted sum of update rows. `updates.len()` may be anything up to
    /// `max_updates`; implementations pad with zero-weight rows.
    fn agg_combine(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>>;

    /// Squared distances ||fresh - stale_s||^2 for each stale row plus
    /// ||fresh||^2 as the final element (len = stale.len() + 1).
    fn agg_dev(&self, fresh: &[f32], stale: &[&[f32]]) -> Result<Vec<f32>>;
}

/// PJRT-loaded executables for one variant.
///
/// SAFETY: `xla::PjRtLoadedExecutable` holds raw pointers and is not marked
/// Send/Sync by the crate, but the XLA CPU PJRT client supports concurrent
/// `Execute` calls on the same loaded executable (each call owns its run
/// state). We serialize compile-time access and allow concurrent execute.
#[cfg(feature = "pjrt")]
struct Loaded {
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    init: xla::PjRtLoadedExecutable,
    agg: xla::PjRtLoadedExecutable,
    dev: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
unsafe impl Send for Loaded {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Loaded {}

#[cfg(feature = "pjrt")]
pub struct PjrtExecutor {
    info: VariantInfo,
    loaded: Loaded,
    /// Cumulative host<->device + execute call counters (perf accounting).
    pub calls: Mutex<HashMap<&'static str, u64>>,
}

/// Stub used when the crate is built without the `pjrt` feature (the `xla`
/// bindings are unavailable offline): `load` always errors, so this type is
/// uninhabited and the `Executor` impl below is unreachable by construction.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtExecutor {
    _uninhabited: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtExecutor {
    pub fn load(_manifest: &Manifest, variant: &str) -> Result<PjrtExecutor> {
        Err(anyhow!(
            "PJRT backend unavailable: built without the `pjrt` feature \
             (variant '{variant}'); use --backend native, or add the `xla` \
             dependency and rebuild with --features pjrt"
        ))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executor for PjrtExecutor {
    fn variant(&self) -> &VariantInfo {
        match self._uninhabited {}
    }

    fn init_params(&self, _seed: i32) -> Result<Vec<f32>> {
        match self._uninhabited {}
    }

    fn train_step(
        &self,
        _params: &[f32],
        _x: &[f32],
        _y: &[i32],
        _mask: &[f32],
        _lr: f32,
    ) -> Result<TrainOut> {
        match self._uninhabited {}
    }

    fn eval_batch(
        &self,
        _params: &[f32],
        _x: &[f32],
        _y: &[i32],
        _mask: &[f32],
    ) -> Result<(f32, f32)> {
        match self._uninhabited {}
    }

    fn agg_combine(&self, _updates: &[&[f32]], _weights: &[f32]) -> Result<Vec<f32>> {
        match self._uninhabited {}
    }

    fn agg_dev(&self, _fresh: &[f32], _stale: &[&[f32]]) -> Result<Vec<f32>> {
        match self._uninhabited {}
    }
}

#[cfg(feature = "pjrt")]
impl PjrtExecutor {
    /// Compile all five computations of `variant` from `manifest`.
    pub fn load(manifest: &Manifest, variant: &str) -> Result<PjrtExecutor> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Self::load_with_client(&client, manifest, variant)
    }

    pub fn load_with_client(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        variant: &str,
    ) -> Result<PjrtExecutor> {
        let info = manifest.variant(variant)?.clone();
        let compile = |comp: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.hlo_path(variant, comp)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(wrap)
                .with_context(|| format!("parsing {path:?}"))?;
            let computation = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&computation)
                .map_err(wrap)
                .with_context(|| format!("compiling {variant}/{comp}"))
        };
        Ok(PjrtExecutor {
            info,
            loaded: Loaded {
                train: compile("train")?,
                eval: compile("eval")?,
                init: compile("init")?,
                agg: compile("agg")?,
                dev: compile("dev")?,
            },
            calls: Mutex::new(HashMap::new()),
        })
    }

    fn count(&self, name: &'static str) {
        *self.calls.lock().expect("call-count mutex poisoned").entry(name).or_insert(0) += 1;
    }

    fn run(
        &self,
        name: &'static str,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.count(name);
        let bufs = exe.execute::<xla::Literal>(args).map_err(wrap)?;
        let lit = bufs[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        lit.to_tuple().map_err(wrap)
    }

    fn pad_updates(&self, updates: &[&[f32]], weights: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let u = self.info.max_updates;
        let p = self.info.num_params;
        if updates.len() > u {
            return Err(anyhow!("{} updates exceed max_updates={u}", updates.len()));
        }
        if updates.len() != weights.len() {
            return Err(anyhow!("updates/weights length mismatch"));
        }
        let mut stacked = vec![0f32; u * p];
        let mut w = vec![0f32; u];
        for (i, row) in updates.iter().enumerate() {
            if row.len() != p {
                return Err(anyhow!("update row {} has len {} != P={p}", i, row.len()));
            }
            stacked[i * p..(i + 1) * p].copy_from_slice(row);
            w[i] = weights[i];
        }
        Ok((stacked, w))
    }
}

#[cfg(feature = "pjrt")]
fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Build an f32 literal of the given shape in ONE copy (avoids the extra
/// full-buffer copy of `Literal::vec1(..).reshape(..)` — §Perf iteration 3).
#[cfg(feature = "pjrt")]
fn literal_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(wrap)
}

#[cfg(feature = "pjrt")]
fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.to_vec::<f32>()
        .map_err(wrap)?
        .first()
        .copied()
        .ok_or_else(|| anyhow!("empty scalar literal"))
}

#[cfg(feature = "pjrt")]
impl Executor for PjrtExecutor {
    fn variant(&self) -> &VariantInfo {
        &self.info
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let args = [xla::Literal::scalar(seed)];
        let out = self.run("init", &self.loaded.init, &args)?;
        out[0].to_vec::<f32>().map_err(wrap)
    }

    fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> Result<TrainOut> {
        let v = &self.info;
        check_batch(v, params, x, y, mask)?;
        let args = [
            literal_f32(&[v.num_params], params)?,
            literal_f32(&[v.batch, v.input_dim], x)?,
            xla::Literal::vec1(y),
            literal_f32(&[v.batch], mask)?,
            xla::Literal::scalar(lr),
        ];
        let out = self.run("train", &self.loaded.train, &args)?;
        Ok(TrainOut {
            params: out[0].to_vec::<f32>().map_err(wrap)?,
            loss: scalar_f32(&out[1])?,
            correct: scalar_f32(&out[2])?,
        })
    }

    fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32], mask: &[f32]) -> Result<(f32, f32)> {
        let v = &self.info;
        check_batch(v, params, x, y, mask)?;
        let args = [
            literal_f32(&[v.num_params], params)?,
            literal_f32(&[v.batch, v.input_dim], x)?,
            xla::Literal::vec1(y),
            literal_f32(&[v.batch], mask)?,
        ];
        let out = self.run("eval", &self.loaded.eval, &args)?;
        Ok((scalar_f32(&out[0])?, scalar_f32(&out[1])?))
    }

    fn agg_combine(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        let (stacked, w) = self.pad_updates(updates, weights)?;
        let v = &self.info;
        let args = [
            literal_f32(&[v.max_updates, v.num_params], &stacked)?,
            literal_f32(&[v.max_updates], &w)?,
        ];
        let out = self.run("agg", &self.loaded.agg, &args)?;
        out[0].to_vec::<f32>().map_err(wrap)
    }

    fn agg_dev(&self, fresh: &[f32], stale: &[&[f32]]) -> Result<Vec<f32>> {
        let v = &self.info;
        if fresh.len() != v.num_params {
            return Err(anyhow!("fresh len {} != P={}", fresh.len(), v.num_params));
        }
        let weights = vec![0f32; stale.len()];
        let (stacked, _) = self.pad_updates(stale, &weights)?;
        let args = [
            literal_f32(&[v.num_params], fresh)?,
            literal_f32(&[v.max_updates, v.num_params], &stacked)?,
        ];
        let out = self.run("dev", &self.loaded.dev, &args)?;
        let full = out[0].to_vec::<f32>().map_err(wrap)?;
        // full = [dist_0..dist_{U-1}, fnorm]; trim padded rows.
        let mut res: Vec<f32> = full[..stale.len()].to_vec();
        res.push(*full.last().ok_or_else(|| anyhow!("empty dev output"))?);
        Ok(res)
    }
}

#[cfg(feature = "pjrt")]
fn check_batch(v: &VariantInfo, params: &[f32], x: &[f32], y: &[i32], mask: &[f32]) -> Result<()> {
    if params.len() != v.num_params {
        return Err(anyhow!("params len {} != P={}", params.len(), v.num_params));
    }
    if x.len() != v.batch * v.input_dim {
        return Err(anyhow!("x len {} != B*D={}", x.len(), v.batch * v.input_dim));
    }
    if y.len() != v.batch || mask.len() != v.batch {
        return Err(anyhow!("y/mask len != B={}", v.batch));
    }
    if let Some(bad) = y.iter().find(|&&l| l < 0 || l as usize >= v.num_classes) {
        return Err(anyhow!("label {bad} out of range 0..{}", v.num_classes));
    }
    Ok(())
}
