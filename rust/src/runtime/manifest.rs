//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json` + `*.hlo.txt`) and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Static description of one AOT-compiled model variant.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantInfo {
    pub name: String,
    pub num_params: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub hidden: Vec<usize>,
    pub batch: usize,
    /// Padded row count of the aggregation kernels (static AOT shape).
    pub max_updates: usize,
    /// NLP-style benchmark: report perplexity = exp(loss) instead of accuracy.
    pub perplexity: bool,
}

impl VariantInfo {
    /// (in, out) dims of each dense layer, matching `model.py`.
    pub fn layer_shapes(&self) -> Vec<(usize, usize)> {
        let mut dims = vec![self.input_dim];
        dims.extend(&self.hidden);
        dims.push(self.num_classes);
        (0..dims.len() - 1).map(|i| (dims[i], dims[i + 1])).collect()
    }
}

/// One exported computation (train/eval/init/agg/dev) of a variant.
#[derive(Clone, Debug)]
pub struct ComputationInfo {
    pub variant: String,
    pub computation: String,
    pub file: String,
    pub sha256: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, VariantInfo>,
    pub computations: Vec<ComputationInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Self::from_json(&json, dir)
    }

    pub fn from_json(json: &Json, dir: PathBuf) -> Result<Manifest> {
        let fmt = json
            .get("format")
            .and_then(|f| f.as_str())
            .ok_or_else(|| anyhow!("manifest missing 'format'"))?;
        if fmt != "hlo-text-v1" {
            return Err(anyhow!("unsupported manifest format {fmt}"));
        }
        let mut variants = BTreeMap::new();
        for (name, v) in json
            .get("variants")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing 'variants'"))?
        {
            let req = |k: &str| -> Result<usize> {
                v.get(k)
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow!("variant {name} missing '{k}'"))
            };
            variants.insert(
                name.clone(),
                VariantInfo {
                    name: name.clone(),
                    num_params: req("num_params")?,
                    input_dim: req("input_dim")?,
                    num_classes: req("num_classes")?,
                    hidden: v
                        .get("hidden")
                        .and_then(|h| h.as_arr())
                        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default(),
                    batch: req("batch")?,
                    max_updates: req("max_updates")?,
                    perplexity: v
                        .get("perplexity")
                        .and_then(|p| p.as_bool())
                        .unwrap_or(false),
                },
            );
        }
        let mut computations = Vec::new();
        for c in json
            .get("computations")
            .and_then(|c| c.as_arr())
            .unwrap_or(&[])
        {
            let get = |k: &str| -> Result<String> {
                c.get(k)
                    .and_then(|x| x.as_str())
                    .map(String::from)
                    .ok_or_else(|| anyhow!("computation entry missing '{k}'"))
            };
            computations.push(ComputationInfo {
                variant: get("variant")?,
                computation: get("computation")?,
                file: get("file")?,
                sha256: get("sha256")?,
            });
        }
        Ok(Manifest { dir, variants, computations })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown variant '{name}' (have: {:?})", self.variants.keys()))
    }

    /// Path of the HLO text file for (variant, computation).
    pub fn hlo_path(&self, variant: &str, computation: &str) -> Result<PathBuf> {
        let c = self
            .computations
            .iter()
            .find(|c| c.variant == variant && c.computation == computation)
            .ok_or_else(|| anyhow!("no computation {variant}/{computation} in manifest"))?;
        Ok(self.dir.join(&c.file))
    }

    /// Consistency: each variant has all five computations, files exist.
    pub fn validate(&self) -> Result<()> {
        for name in self.variants.keys() {
            for comp in ["train", "eval", "init", "agg", "dev"] {
                let p = self.hlo_path(name, comp)?;
                if !p.exists() {
                    return Err(anyhow!("artifact file missing: {p:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
          "format": "hlo-text-v1",
          "variants": {
            "tiny": {"num_params": 172, "input_dim": 16, "num_classes": 4,
                     "hidden": [8], "batch": 4, "max_updates": 8,
                     "perplexity": false}
          },
          "computations": [
            {"variant": "tiny", "computation": "train",
             "file": "tiny_train.hlo.txt", "sha256": "ab",
             "arg_shapes": [[172]], "arg_dtypes": ["float32"]}
          ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_variants_and_computations() {
        let m = Manifest::from_json(&sample_json(), PathBuf::from("/tmp")).unwrap();
        let v = m.variant("tiny").unwrap();
        assert_eq!(v.num_params, 172);
        assert_eq!(v.layer_shapes(), vec![(16, 8), (8, 4)]);
        assert_eq!(
            m.hlo_path("tiny", "train").unwrap(),
            PathBuf::from("/tmp/tiny_train.hlo.txt")
        );
    }

    #[test]
    fn unknown_variant_errors() {
        let m = Manifest::from_json(&sample_json(), PathBuf::from("/tmp")).unwrap();
        assert!(m.variant("nope").is_err());
        assert!(m.hlo_path("tiny", "missing").is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let j = Json::parse(r#"{"format": "v0", "variants": {}}"#).unwrap();
        assert!(Manifest::from_json(&j, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            m.validate().unwrap();
            assert!(m.variants.contains_key("tiny"));
            let v = m.variant("speech").unwrap();
            // P must equal sum over layers of i*o + o
            let p: usize = v.layer_shapes().iter().map(|(i, o)| i * o + o).sum();
            assert_eq!(p, v.num_params);
        }
    }
}
