//! Experiment configuration: every knob of the coordinator, with benchmark
//! presets mirroring paper Table 1, JSON load/save, and validation.

use anyhow::{anyhow, Result};

use crate::aggregation::scaling::ScalingRule;
use crate::data::partition::PartitionScheme;
use crate::learners::HardwareScenario;
use crate::scenario::faults::FaultConfig;
use crate::util::json::{arr, num, obj, Json};

/// Round-termination regime (paper §5.1 "Experimental Scenarios", plus the
/// buffered-asynchronous regime the SAA idea generalizes to).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RoundMode {
    /// OC: over-commit the target by `factor` (1.3 in the paper) and end
    /// the round once `target` updates arrive.
    OverCommit { factor: f64 },
    /// DL: select `target` and aggregate whatever arrives by `deadline`.
    Deadline { deadline: f64 },
    /// ASYNC: FedBuff-style buffered aggregation on the event kernel. The
    /// server keeps `target_participants` tasks in flight (selection is
    /// re-triggered per departure, not per round), merges every `buffer_k`
    /// arrivals with Eq.-2 staleness weights, and discards updates older
    /// than `max_staleness` model versions (`None` = keep everything).
    /// `cfg.rounds` counts merges; `cfg.apt` is ignored (there is no
    /// round-synchronous target to shrink).
    Async { buffer_k: usize, max_staleness: Option<usize> },
}

impl RoundMode {
    pub fn label(&self) -> &'static str {
        match self {
            RoundMode::OverCommit { .. } => "OC",
            RoundMode::Deadline { .. } => "DL",
            RoundMode::Async { .. } => "ASYNC",
        }
    }
}

/// Availability regime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AvailMode {
    AllAvail,
    DynAvail,
}

/// One experiment, fully specified.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub label: String,
    /// Model/benchmark variant name ("speech", "cifar", ...).
    pub variant: String,
    pub total_learners: usize,
    pub rounds: usize,
    /// Developer-set target participants per round (N_0).
    pub target_participants: usize,
    pub mode: RoundMode,
    pub avail: AvailMode,
    /// Selector: "random" | "oort" | "priority" | "safa".
    pub selector: String,
    /// Staleness-aware aggregation enabled (RELAY's SAA / SAFA's cache).
    pub use_saa: bool,
    pub scaling: ScalingRule,
    /// Max staleness in rounds; None = unbounded (RELAY default).
    pub staleness_threshold: Option<usize>,
    /// RELAY's Adaptive Participant Target.
    pub apt: bool,
    /// EMA alpha for the round-duration estimate (paper: 0.25).
    pub apt_alpha: f64,
    /// Server optimizer: "fedavg" | "yogi".
    pub server_opt: String,
    /// Local SGD learning rate + epochs (Table 1).
    pub lr: f32,
    pub local_epochs: usize,
    pub partition: PartitionScheme,
    /// Mean samples per learner shard.
    pub mean_samples: usize,
    pub hardware: HardwareScenario,
    /// SAFA's target fraction of participants that ends a round.
    pub safa_target_ratio: f64,
    /// SAFA+O oracle: perfect knowledge of which stale updates will be
    /// aggregated; never spends resources on doomed updates.
    pub oracle: bool,
    /// Floor on round duration (seconds): the selection window +
    /// configuration/model-distribution phases of Fig. 1. Real deployments
    /// report multi-minute rounds even when all updates arrive quickly
    /// (Bonawitz et al.); this keeps scaled-down OC rounds from collapsing
    /// to a frozen availability snapshot.
    pub min_round_duration: f64,
    /// Rounds a participant holds from re-checking in after submitting.
    pub cooldown_rounds: usize,
    /// Evaluate on the test set every this many rounds.
    pub eval_every: usize,
    /// Test-set size: samples per class.
    pub test_per_class: usize,
    pub seed: u64,
    /// Worker threads for the per-participant training loop.
    pub workers: usize,
    /// Width of the intra-round training pool (the per-participant local-SGD
    /// fan-out). 0 = inherit `workers`; 1 = strictly serial; N = N lanes.
    /// Results are byte-identical at any width — outcomes are committed in a
    /// fixed reduction order, never completion order (the fuzz harness and
    /// `tests/train_parallel_props.rs` pin this).
    pub train_workers: usize,
    /// Number of contiguous id-range coordinator shards the population
    /// substrate (registry, availability index, eligible set, selection
    /// indexes) is partitioned into. 0 = autodetect from the core count.
    /// Results are byte-identical for any K — the shard count only governs
    /// how much of the per-round advance+select work can run in parallel
    /// (`tests/coord_shard_props.rs` and the fuzzer coord-shards axis pin
    /// this).
    pub coord_shards: usize,
    /// Deterministic fault injection (all-off by default); see
    /// [`crate::scenario::faults`].
    pub faults: FaultConfig,
    /// Number of concurrent jobs sharing one device fleet. 1 = the classic
    /// single-job engines; N > 1 routes the run through
    /// [`crate::jobs::run_jobset`], where every job has its own model,
    /// selector, round mode, and target count, all drawing from one shared
    /// registry/availability index (a device busy on job A is ineligible
    /// for job B).
    pub jobs: usize,
    /// Cross-job arbitration policy: "fair" (least device-seconds spent
    /// claims first) | "priority" (strict `job_priorities` order).
    pub job_policy: String,
    /// Per-job priorities for the "priority" policy (higher claims first).
    /// Empty = all equal; otherwise one entry per job.
    pub job_priorities: Vec<u64>,
    /// Per-job selector overrides. Empty = every job inherits `selector`.
    pub job_selectors: Vec<String>,
    /// Per-job round-mode overrides as compact specs ("oc", "oc1.5",
    /// "dl60", "async4"; bare kinds inherit the base `mode`'s parameters).
    /// Empty = every job inherits `mode`.
    pub job_modes: Vec<String>,
    /// Per-job target-participant overrides. Empty = every job inherits
    /// `target_participants`.
    pub job_targets: Vec<usize>,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            label: String::new(),
            variant: "speech".into(),
            total_learners: 200,
            rounds: 200,
            target_participants: 10,
            mode: RoundMode::OverCommit { factor: 1.3 },
            avail: AvailMode::DynAvail,
            selector: "random".into(),
            use_saa: false,
            scaling: ScalingRule::Relay { beta: 0.35 },
            staleness_threshold: None,
            apt: false,
            apt_alpha: 0.25,
            server_opt: "fedavg".into(),
            lr: 0.05,
            local_epochs: 1,
            partition: PartitionScheme::UniformIid,
            mean_samples: 100,
            hardware: HardwareScenario::Hs1,
            safa_target_ratio: 0.1,
            oracle: false,
            min_round_duration: 30.0,
            cooldown_rounds: 5,
            eval_every: 5,
            test_per_class: 20,
            seed: 1,
            workers: 0,       // 0 = auto
            train_workers: 0, // 0 = inherit `workers`
            coord_shards: 0,  // 0 = autodetect
            faults: FaultConfig::default(),
            jobs: 1,
            job_policy: "fair".into(),
            job_priorities: Vec::new(),
            job_selectors: Vec::new(),
            job_modes: Vec::new(),
            job_targets: Vec::new(),
        }
    }
}

impl ExpConfig {
    /// RELAY's full configuration (IPS + SAA + APT) on top of `self`.
    pub fn relay(mut self) -> Self {
        self.selector = "priority".into();
        self.use_saa = true;
        self.scaling = ScalingRule::Relay { beta: 0.35 };
        self.apt = true;
        self
    }

    pub fn with_label(mut self, label: impl Into<String>) -> Self {
        self.label = label.into();
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.total_learners == 0 || self.rounds == 0 {
            return Err(anyhow!("learners/rounds must be positive"));
        }
        if self.target_participants == 0 {
            return Err(anyhow!("target_participants must be >= 1"));
        }
        if self.target_participants > self.total_learners {
            return Err(anyhow!(
                "target_participants ({}) exceeds total_learners ({})",
                self.target_participants,
                self.total_learners
            ));
        }
        if !(0.0..=1.0).contains(&self.safa_target_ratio) {
            return Err(anyhow!("safa_target_ratio must be in [0,1]"));
        }
        if let RoundMode::OverCommit { factor } = self.mode {
            if factor < 1.0 {
                return Err(anyhow!("overcommit factor must be >= 1"));
            }
        }
        if let RoundMode::Deadline { deadline } = self.mode {
            if deadline <= 0.0 {
                return Err(anyhow!("deadline must be positive"));
            }
        }
        if let RoundMode::Async { buffer_k, .. } = self.mode {
            if buffer_k == 0 {
                return Err(anyhow!("async buffer_k must be >= 1"));
            }
            if self.oracle {
                return Err(anyhow!(
                    "the SAFA+O oracle is defined only for round-synchronous (OC/DL) modes"
                ));
            }
        }
        self.faults.validate()?;
        if crate::selection::by_name(&self.selector).is_none() {
            return Err(anyhow!("unknown selector '{}'", self.selector));
        }
        if crate::aggregation::by_name(&self.server_opt).is_none() {
            return Err(anyhow!("unknown server optimizer '{}'", self.server_opt));
        }
        // parallelism knobs are machine-sized, not population-sized: any
        // value relative to the learner count is legal (shard counts larger
        // than the population are deliberately exercised by
        // tests/coord_shard_props.rs), but a value beyond any plausible
        // core count is a typo, not a request
        const MAX_PARALLELISM: usize = 4096;
        if self.workers > MAX_PARALLELISM {
            return Err(anyhow!("workers ({}) > {MAX_PARALLELISM} is absurd", self.workers));
        }
        if self.train_workers > MAX_PARALLELISM {
            return Err(anyhow!(
                "train_workers ({}) > {MAX_PARALLELISM} is absurd",
                self.train_workers
            ));
        }
        if self.coord_shards > MAX_PARALLELISM {
            return Err(anyhow!(
                "coord_shards ({}) > {MAX_PARALLELISM} is absurd",
                self.coord_shards
            ));
        }
        if self.jobs == 0 || self.jobs > 64 {
            return Err(anyhow!("jobs must be in 1..=64, got {}", self.jobs));
        }
        if !matches!(self.job_policy.as_str(), "fair" | "priority") {
            return Err(anyhow!("unknown job_policy '{}' (fair|priority)", self.job_policy));
        }
        for (name, len) in [
            ("job_priorities", self.job_priorities.len()),
            ("job_selectors", self.job_selectors.len()),
            ("job_modes", self.job_modes.len()),
            ("job_targets", self.job_targets.len()),
        ] {
            if len != 0 && len != self.jobs {
                return Err(anyhow!(
                    "{name} must be empty or hold one entry per job ({len} != {})",
                    self.jobs
                ));
            }
        }
        for s in &self.job_selectors {
            if crate::selection::by_name(s).is_none() {
                return Err(anyhow!("unknown job selector '{s}'"));
            }
        }
        for m in &self.job_modes {
            crate::jobs::parse_job_mode(m, &self.mode)?;
        }
        for (i, &t) in self.job_targets.iter().enumerate() {
            if t == 0 || t > self.total_learners {
                return Err(anyhow!(
                    "job_targets[{i}] = {t} must be in 1..=total_learners ({})",
                    self.total_learners
                ));
            }
        }
        if self.jobs > 1 && self.oracle {
            return Err(anyhow!("the SAFA+O oracle is single-job only"));
        }
        if self.jobs > 1 && self.apt {
            return Err(anyhow!("APT is single-job only (got jobs = {})", self.jobs));
        }
        Ok(())
    }

    // ---- JSON -----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        // mode_param carries the regime's primary knob (OC factor, DL
        // deadline, async buffer size); mode_staleness is async-only.
        let (mode, mode_param, mode_staleness) = match self.mode {
            RoundMode::OverCommit { factor } => ("oc", factor, None),
            RoundMode::Deadline { deadline } => ("dl", deadline, None),
            RoundMode::Async { buffer_k, max_staleness } => {
                ("async", buffer_k as f64, max_staleness)
            }
        };
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("variant", Json::Str(self.variant.clone())),
            ("total_learners", num(self.total_learners as f64)),
            ("rounds", num(self.rounds as f64)),
            ("target_participants", num(self.target_participants as f64)),
            ("mode", Json::Str(mode.into())),
            ("mode_param", num(mode_param)),
            (
                "mode_staleness",
                mode_staleness.map(|t| num(t as f64)).unwrap_or(Json::Null),
            ),
            (
                "avail",
                Json::Str(match self.avail {
                    AvailMode::AllAvail => "all".into(),
                    AvailMode::DynAvail => "dyn".into(),
                }),
            ),
            ("selector", Json::Str(self.selector.clone())),
            ("use_saa", Json::Bool(self.use_saa)),
            ("scaling", Json::Str(self.scaling.label().into())),
            (
                "staleness_threshold",
                self.staleness_threshold.map(|t| num(t as f64)).unwrap_or(Json::Null),
            ),
            ("apt", Json::Bool(self.apt)),
            ("apt_alpha", num(self.apt_alpha)),
            ("server_opt", Json::Str(self.server_opt.clone())),
            ("lr", num(self.lr as f64)),
            ("local_epochs", num(self.local_epochs as f64)),
            ("partition", Json::Str(self.partition.label())),
            ("mean_samples", num(self.mean_samples as f64)),
            (
                "hardware",
                Json::Str(
                    match self.hardware {
                        HardwareScenario::Hs1 => "hs1",
                        HardwareScenario::Hs2 => "hs2",
                        HardwareScenario::Hs3 => "hs3",
                        HardwareScenario::Hs4 => "hs4",
                    }
                    .into(),
                ),
            ),
            ("safa_target_ratio", num(self.safa_target_ratio)),
            ("oracle", Json::Bool(self.oracle)),
            ("min_round_duration", num(self.min_round_duration)),
            ("cooldown_rounds", num(self.cooldown_rounds as f64)),
            ("eval_every", num(self.eval_every as f64)),
            ("test_per_class", num(self.test_per_class as f64)),
            ("seed", num(self.seed as f64)),
            ("workers", num(self.workers as f64)),
            ("train_workers", num(self.train_workers as f64)),
            ("coord_shards", num(self.coord_shards as f64)),
            ("faults", self.faults.to_json()),
            ("jobs", num(self.jobs as f64)),
            ("job_policy", Json::Str(self.job_policy.clone())),
            (
                "job_priorities",
                arr(self.job_priorities.iter().map(|&p| num(p as f64))),
            ),
            (
                "job_selectors",
                arr(self.job_selectors.iter().map(|s| Json::Str(s.clone()))),
            ),
            ("job_modes", arr(self.job_modes.iter().map(|m| Json::Str(m.clone())))),
            ("job_targets", arr(self.job_targets.iter().map(|&t| num(t as f64)))),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ExpConfig> {
        let d = ExpConfig::default();
        let gs = |k: &str, dflt: &str| -> String {
            j.get(k).and_then(|v| v.as_str()).unwrap_or(dflt).to_string()
        };
        let gu = |k: &str, dflt: usize| j.get(k).and_then(|v| v.as_usize()).unwrap_or(dflt);
        let gf = |k: &str, dflt: f64| j.get(k).and_then(|v| v.as_f64()).unwrap_or(dflt);
        let gb = |k: &str, dflt: bool| j.get(k).and_then(|v| v.as_bool()).unwrap_or(dflt);
        let ga = |k: &str| -> Vec<Json> {
            j.get(k).and_then(|v| v.as_arr()).map(|a| a.to_vec()).unwrap_or_default()
        };

        let mode = match gs("mode", "oc").as_str() {
            "oc" => RoundMode::OverCommit { factor: gf("mode_param", 1.3) },
            "dl" => RoundMode::Deadline { deadline: gf("mode_param", 100.0) },
            "async" => RoundMode::Async {
                buffer_k: gf("mode_param", 10.0) as usize,
                max_staleness: j.get("mode_staleness").and_then(|v| v.as_usize()),
            },
            m => return Err(anyhow!("unknown mode '{m}'")),
        };
        let avail = match gs("avail", "dyn").as_str() {
            "all" => AvailMode::AllAvail,
            "dyn" => AvailMode::DynAvail,
            a => return Err(anyhow!("unknown avail '{a}'")),
        };
        let partition = PartitionScheme::parse(&gs("partition", "iid"))
            .ok_or_else(|| anyhow!("unknown partition"))?;
        let scaling = ScalingRule::parse(&gs("scaling", "relay"))
            .ok_or_else(|| anyhow!("unknown scaling"))?;
        let hardware = HardwareScenario::parse(&gs("hardware", "hs1"))
            .ok_or_else(|| anyhow!("unknown hardware scenario"))?;
        let cfg = ExpConfig {
            label: gs("label", ""),
            variant: gs("variant", &d.variant),
            total_learners: gu("total_learners", d.total_learners),
            rounds: gu("rounds", d.rounds),
            target_participants: gu("target_participants", d.target_participants),
            mode,
            avail,
            selector: gs("selector", &d.selector),
            use_saa: gb("use_saa", d.use_saa),
            scaling,
            staleness_threshold: j
                .get("staleness_threshold")
                .and_then(|v| v.as_usize()),
            apt: gb("apt", d.apt),
            apt_alpha: gf("apt_alpha", d.apt_alpha),
            server_opt: gs("server_opt", &d.server_opt),
            lr: gf("lr", d.lr as f64) as f32,
            local_epochs: gu("local_epochs", d.local_epochs),
            partition,
            mean_samples: gu("mean_samples", d.mean_samples),
            hardware,
            safa_target_ratio: gf("safa_target_ratio", d.safa_target_ratio),
            oracle: gb("oracle", d.oracle),
            min_round_duration: gf("min_round_duration", d.min_round_duration),
            cooldown_rounds: gu("cooldown_rounds", d.cooldown_rounds),
            eval_every: gu("eval_every", d.eval_every),
            test_per_class: gu("test_per_class", d.test_per_class),
            seed: gf("seed", d.seed as f64) as u64,
            workers: gu("workers", d.workers),
            train_workers: gu("train_workers", d.train_workers),
            coord_shards: gu("coord_shards", d.coord_shards),
            faults: j.get("faults").map(FaultConfig::from_json).unwrap_or_default(),
            jobs: gu("jobs", d.jobs),
            job_policy: gs("job_policy", &d.job_policy),
            job_priorities: ga("job_priorities")
                .iter()
                .filter_map(|v| v.as_usize())
                .map(|p| p as u64)
                .collect(),
            job_selectors: ga("job_selectors")
                .iter()
                .filter_map(|v| v.as_str())
                .map(str::to_string)
                .collect(),
            job_modes: ga("job_modes")
                .iter()
                .filter_map(|v| v.as_str())
                .map(str::to_string)
                .collect(),
            job_targets: ga("job_targets").iter().filter_map(|v| v.as_usize()).collect(),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<ExpConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }
}

/// Benchmark presets mirroring paper Table 1 (scaled: DESIGN.md §2).
pub fn preset(benchmark: &str) -> Result<ExpConfig> {
    let mut c = ExpConfig::default();
    match benchmark {
        "speech" => {
            c.variant = "speech".into();
            c.lr = 0.05;
            c.local_epochs = 1;
            c.server_opt = "yogi".into();
        }
        "cifar" => {
            c.variant = "cifar".into();
            c.lr = 0.05;
            c.local_epochs = 1;
            c.server_opt = "fedavg".into(); // paper: FedAvg for CIFAR10
        }
        "openimage" => {
            c.variant = "openimage".into();
            c.lr = 0.05;
            c.local_epochs = 2;
            c.server_opt = "yogi".into();
        }
        "nlp" => {
            c.variant = "nlp".into();
            c.lr = 0.02;
            c.local_epochs = 2;
            c.server_opt = "yogi".into();
        }
        "tiny" => {
            c.variant = "tiny".into();
            c.lr = 0.1;
            c.mean_samples = 20;
            c.test_per_class = 10;
        }
        other => return Err(anyhow!("unknown benchmark preset '{other}'")),
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::LabelSkew;

    #[test]
    fn default_validates() {
        ExpConfig::default().validate().unwrap();
    }

    #[test]
    fn relay_builder_sets_modules() {
        let c = ExpConfig::default().relay();
        assert_eq!(c.selector, "priority");
        assert!(c.use_saa);
        assert!(c.apt);
        assert_eq!(c.scaling.label(), "relay");
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut c = ExpConfig::default().relay().with_label("x");
        c.mode = RoundMode::Deadline { deadline: 100.0 };
        c.avail = AvailMode::AllAvail;
        c.staleness_threshold = Some(5);
        c.partition = PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Zipf };
        c.hardware = HardwareScenario::Hs3;
        c.oracle = true;
        c.train_workers = 5;
        c.coord_shards = 7;
        c.faults = FaultConfig {
            flap: 0.125,
            crash: 0.25,
            delay_secs: 64.0,
            fault_seed: 77,
            ..Default::default()
        };
        let j = c.to_json();
        let c2 = ExpConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(c2.label, "x");
        assert_eq!(c2.mode, RoundMode::Deadline { deadline: 100.0 });
        assert_eq!(c2.avail, AvailMode::AllAvail);
        assert_eq!(c2.staleness_threshold, Some(5));
        assert_eq!(c2.partition.label(), "label-zipf");
        assert_eq!(c2.hardware, HardwareScenario::Hs3);
        assert!(c2.oracle);
        assert_eq!(c2.selector, "priority");
        assert_eq!(c2.faults, c.faults);
        assert_eq!(c2.train_workers, 5);
        assert_eq!(c2.coord_shards, 7);
    }

    #[test]
    fn configs_without_train_workers_key_inherit_workers() {
        // pre-train-pool config files (no "train_workers" key) load as 0 =
        // inherit `workers`, which is the pre-PR behavior bit-for-bit
        let parsed = Json::parse(r#"{"mode": "oc", "workers": 3}"#).unwrap();
        let c = ExpConfig::from_json(&parsed).unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.train_workers, 0);
    }

    #[test]
    fn configs_without_coord_shards_key_autodetect() {
        // pre-sharded-coordination config files (no "coord_shards" key)
        // load as 0 = autodetect, which is byte-identical to any other K
        // by the shard-invariance contract
        let parsed = Json::parse(r#"{"mode": "oc", "workers": 3}"#).unwrap();
        let c = ExpConfig::from_json(&parsed).unwrap();
        assert_eq!(c.coord_shards, 0);
    }

    #[test]
    fn configs_without_faults_key_load_as_fault_free() {
        // a pre-fault-layer config file (no "faults" object) loads all-off
        let parsed = Json::parse(r#"{"mode": "oc", "selector": "oort"}"#).unwrap();
        let mut c = ExpConfig::from_json(&parsed).unwrap();
        assert!(!c.faults.is_active());
        assert_eq!(c.selector, "oort");
        c.faults.crash = 1.5;
        assert!(c.validate().is_err(), "bad fault rates must be rejected");
    }

    #[test]
    fn async_json_roundtrip() {
        let mut c = ExpConfig::default().with_label("async");
        c.mode = RoundMode::Async { buffer_k: 7, max_staleness: Some(3) };
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        let c2 = ExpConfig::from_json(&j).unwrap();
        assert_eq!(c2.mode, RoundMode::Async { buffer_k: 7, max_staleness: Some(3) });
        assert_eq!(c2.mode.label(), "ASYNC");

        c.mode = RoundMode::Async { buffer_k: 1, max_staleness: None };
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        let c2 = ExpConfig::from_json(&j).unwrap();
        assert_eq!(c2.mode, RoundMode::Async { buffer_k: 1, max_staleness: None });
    }

    #[test]
    fn rejects_bad_async_configs() {
        let mut c = ExpConfig::default();
        c.mode = RoundMode::Async { buffer_k: 0, max_staleness: None };
        assert!(c.validate().is_err());
        let mut c = ExpConfig::default();
        c.mode = RoundMode::Async { buffer_k: 4, max_staleness: Some(2) };
        c.oracle = true;
        assert!(c.validate().is_err());
        c.oracle = false;
        c.validate().unwrap();
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = ExpConfig::default();
        c.target_participants = 0;
        assert!(c.validate().is_err());
        let mut c = ExpConfig::default();
        c.selector = "nope".into();
        assert!(c.validate().is_err());
        let mut c = ExpConfig::default();
        c.mode = RoundMode::OverCommit { factor: 0.5 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_target_exceeding_population() {
        let mut c = ExpConfig::default();
        c.total_learners = 8;
        c.target_participants = 9;
        assert!(c.validate().is_err(), "target > population must be rejected");
        c.target_participants = 8;
        c.validate().unwrap();
    }

    #[test]
    fn rejects_non_finite_fault_delay() {
        let mut c = ExpConfig::default();
        c.faults.delay = 0.2;
        c.faults.delay_secs = f64::NAN;
        assert!(c.validate().is_err(), "NaN delay_secs must be rejected");
        c.faults.delay_secs = f64::INFINITY;
        assert!(c.validate().is_err(), "infinite delay_secs must be rejected");
        c.faults.delay_secs = -1.0;
        assert!(c.validate().is_err(), "negative delay_secs must be rejected");
        c.faults.delay_secs = 120.0;
        c.validate().unwrap();
    }

    #[test]
    fn rejects_absurd_parallelism_knobs() {
        let cases: Vec<fn(&mut ExpConfig)> = vec![
            |c| c.workers = 5000,
            |c| c.train_workers = 1 << 20,
            |c| c.coord_shards = 4097,
        ];
        for (i, set) in cases.into_iter().enumerate() {
            let mut c = ExpConfig::default();
            set(&mut c);
            assert!(c.validate().is_err(), "absurd knob case {i} must be rejected");
        }
        // values above the learner count stay legal: the K-invariance suite
        // deliberately runs K=16 coordinator shards on 14-learner cells
        let mut c = ExpConfig::default();
        c.total_learners = 14;
        c.target_participants = 4;
        c.coord_shards = 16;
        c.workers = 64;
        c.validate().unwrap();
    }

    #[test]
    fn job_fields_roundtrip_and_validate() {
        let mut c = ExpConfig::default().with_label("mj");
        c.jobs = 3;
        c.job_policy = "priority".into();
        c.job_priorities = vec![5, 1, 9];
        c.job_selectors = vec!["random".into(), "oort".into(), "random".into()];
        c.job_modes = vec!["oc".into(), "dl60".into(), "async4".into()];
        c.job_targets = vec![4, 2, 6];
        c.validate().unwrap();
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        let c2 = ExpConfig::from_json(&j).unwrap();
        assert_eq!(c2.jobs, 3);
        assert_eq!(c2.job_policy, "priority");
        assert_eq!(c2.job_priorities, vec![5, 1, 9]);
        assert_eq!(c2.job_selectors, c.job_selectors);
        assert_eq!(c2.job_modes, c.job_modes);
        assert_eq!(c2.job_targets, vec![4, 2, 6]);
    }

    #[test]
    fn configs_without_job_keys_load_single_job() {
        // pre-multi-job config files (no job keys) load as the classic
        // single-job shape, bit-for-bit
        let parsed = Json::parse(r#"{"mode": "oc", "workers": 3}"#).unwrap();
        let c = ExpConfig::from_json(&parsed).unwrap();
        assert_eq!(c.jobs, 1);
        assert_eq!(c.job_policy, "fair");
        assert!(c.job_priorities.is_empty());
        assert!(c.job_selectors.is_empty());
        assert!(c.job_modes.is_empty());
        assert!(c.job_targets.is_empty());
    }

    #[test]
    fn rejects_bad_job_configs() {
        let cases: Vec<fn(&mut ExpConfig)> = vec![
            |c| c.jobs = 0,
            |c| c.jobs = 65,
            |c| c.job_policy = "market".into(),
            |c| {
                c.jobs = 2;
                c.job_priorities = vec![1];
            },
            |c| c.job_selectors = vec!["nope".into()],
            |c| c.job_modes = vec!["warp9".into()],
            |c| c.job_targets = vec![0],
            |c| c.job_targets = vec![c.total_learners + 1],
            |c| {
                c.jobs = 2;
                c.oracle = true;
            },
            |c| {
                c.jobs = 2;
                c.apt = true;
            },
        ];
        for (i, set) in cases.into_iter().enumerate() {
            let mut c = ExpConfig::default();
            set(&mut c);
            assert!(c.validate().is_err(), "bad job config case {i} must be rejected");
        }
    }

    #[test]
    fn presets_follow_table1() {
        assert_eq!(preset("cifar").unwrap().server_opt, "fedavg");
        assert_eq!(preset("speech").unwrap().server_opt, "yogi");
        assert!(preset("imagenet").is_err());
    }
}
