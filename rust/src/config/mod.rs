//! Experiment configuration: every knob of the coordinator, with benchmark
//! presets mirroring paper Table 1, JSON load/save, and validation.

use anyhow::{anyhow, Result};

use crate::aggregation::scaling::ScalingRule;
use crate::data::partition::PartitionScheme;
use crate::learners::HardwareScenario;
use crate::scenario::faults::FaultConfig;
use crate::util::json::{num, obj, Json};

/// Round-termination regime (paper §5.1 "Experimental Scenarios", plus the
/// buffered-asynchronous regime the SAA idea generalizes to).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RoundMode {
    /// OC: over-commit the target by `factor` (1.3 in the paper) and end
    /// the round once `target` updates arrive.
    OverCommit { factor: f64 },
    /// DL: select `target` and aggregate whatever arrives by `deadline`.
    Deadline { deadline: f64 },
    /// ASYNC: FedBuff-style buffered aggregation on the event kernel. The
    /// server keeps `target_participants` tasks in flight (selection is
    /// re-triggered per departure, not per round), merges every `buffer_k`
    /// arrivals with Eq.-2 staleness weights, and discards updates older
    /// than `max_staleness` model versions (`None` = keep everything).
    /// `cfg.rounds` counts merges; `cfg.apt` is ignored (there is no
    /// round-synchronous target to shrink).
    Async { buffer_k: usize, max_staleness: Option<usize> },
}

impl RoundMode {
    pub fn label(&self) -> &'static str {
        match self {
            RoundMode::OverCommit { .. } => "OC",
            RoundMode::Deadline { .. } => "DL",
            RoundMode::Async { .. } => "ASYNC",
        }
    }
}

/// Availability regime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AvailMode {
    AllAvail,
    DynAvail,
}

/// One experiment, fully specified.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub label: String,
    /// Model/benchmark variant name ("speech", "cifar", ...).
    pub variant: String,
    pub total_learners: usize,
    pub rounds: usize,
    /// Developer-set target participants per round (N_0).
    pub target_participants: usize,
    pub mode: RoundMode,
    pub avail: AvailMode,
    /// Selector: "random" | "oort" | "priority" | "safa".
    pub selector: String,
    /// Staleness-aware aggregation enabled (RELAY's SAA / SAFA's cache).
    pub use_saa: bool,
    pub scaling: ScalingRule,
    /// Max staleness in rounds; None = unbounded (RELAY default).
    pub staleness_threshold: Option<usize>,
    /// RELAY's Adaptive Participant Target.
    pub apt: bool,
    /// EMA alpha for the round-duration estimate (paper: 0.25).
    pub apt_alpha: f64,
    /// Server optimizer: "fedavg" | "yogi".
    pub server_opt: String,
    /// Local SGD learning rate + epochs (Table 1).
    pub lr: f32,
    pub local_epochs: usize,
    pub partition: PartitionScheme,
    /// Mean samples per learner shard.
    pub mean_samples: usize,
    pub hardware: HardwareScenario,
    /// SAFA's target fraction of participants that ends a round.
    pub safa_target_ratio: f64,
    /// SAFA+O oracle: perfect knowledge of which stale updates will be
    /// aggregated; never spends resources on doomed updates.
    pub oracle: bool,
    /// Floor on round duration (seconds): the selection window +
    /// configuration/model-distribution phases of Fig. 1. Real deployments
    /// report multi-minute rounds even when all updates arrive quickly
    /// (Bonawitz et al.); this keeps scaled-down OC rounds from collapsing
    /// to a frozen availability snapshot.
    pub min_round_duration: f64,
    /// Rounds a participant holds from re-checking in after submitting.
    pub cooldown_rounds: usize,
    /// Evaluate on the test set every this many rounds.
    pub eval_every: usize,
    /// Test-set size: samples per class.
    pub test_per_class: usize,
    pub seed: u64,
    /// Worker threads for the per-participant training loop.
    pub workers: usize,
    /// Width of the intra-round training pool (the per-participant local-SGD
    /// fan-out). 0 = inherit `workers`; 1 = strictly serial; N = N lanes.
    /// Results are byte-identical at any width — outcomes are committed in a
    /// fixed reduction order, never completion order (the fuzz harness and
    /// `tests/train_parallel_props.rs` pin this).
    pub train_workers: usize,
    /// Number of contiguous id-range coordinator shards the population
    /// substrate (registry, availability index, eligible set, selection
    /// indexes) is partitioned into. 0 = autodetect from the core count.
    /// Results are byte-identical for any K — the shard count only governs
    /// how much of the per-round advance+select work can run in parallel
    /// (`tests/coord_shard_props.rs` and the fuzzer coord-shards axis pin
    /// this).
    pub coord_shards: usize,
    /// Deterministic fault injection (all-off by default); see
    /// [`crate::scenario::faults`].
    pub faults: FaultConfig,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            label: String::new(),
            variant: "speech".into(),
            total_learners: 200,
            rounds: 200,
            target_participants: 10,
            mode: RoundMode::OverCommit { factor: 1.3 },
            avail: AvailMode::DynAvail,
            selector: "random".into(),
            use_saa: false,
            scaling: ScalingRule::Relay { beta: 0.35 },
            staleness_threshold: None,
            apt: false,
            apt_alpha: 0.25,
            server_opt: "fedavg".into(),
            lr: 0.05,
            local_epochs: 1,
            partition: PartitionScheme::UniformIid,
            mean_samples: 100,
            hardware: HardwareScenario::Hs1,
            safa_target_ratio: 0.1,
            oracle: false,
            min_round_duration: 30.0,
            cooldown_rounds: 5,
            eval_every: 5,
            test_per_class: 20,
            seed: 1,
            workers: 0,       // 0 = auto
            train_workers: 0, // 0 = inherit `workers`
            coord_shards: 0,  // 0 = autodetect
            faults: FaultConfig::default(),
        }
    }
}

impl ExpConfig {
    /// RELAY's full configuration (IPS + SAA + APT) on top of `self`.
    pub fn relay(mut self) -> Self {
        self.selector = "priority".into();
        self.use_saa = true;
        self.scaling = ScalingRule::Relay { beta: 0.35 };
        self.apt = true;
        self
    }

    pub fn with_label(mut self, label: impl Into<String>) -> Self {
        self.label = label.into();
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.total_learners == 0 || self.rounds == 0 {
            return Err(anyhow!("learners/rounds must be positive"));
        }
        if self.target_participants == 0 {
            return Err(anyhow!("target_participants must be >= 1"));
        }
        if !(0.0..=1.0).contains(&self.safa_target_ratio) {
            return Err(anyhow!("safa_target_ratio must be in [0,1]"));
        }
        if let RoundMode::OverCommit { factor } = self.mode {
            if factor < 1.0 {
                return Err(anyhow!("overcommit factor must be >= 1"));
            }
        }
        if let RoundMode::Deadline { deadline } = self.mode {
            if deadline <= 0.0 {
                return Err(anyhow!("deadline must be positive"));
            }
        }
        if let RoundMode::Async { buffer_k, .. } = self.mode {
            if buffer_k == 0 {
                return Err(anyhow!("async buffer_k must be >= 1"));
            }
            if self.oracle {
                return Err(anyhow!(
                    "the SAFA+O oracle is defined only for round-synchronous (OC/DL) modes"
                ));
            }
        }
        self.faults.validate()?;
        if crate::selection::by_name(&self.selector).is_none() {
            return Err(anyhow!("unknown selector '{}'", self.selector));
        }
        if crate::aggregation::by_name(&self.server_opt).is_none() {
            return Err(anyhow!("unknown server optimizer '{}'", self.server_opt));
        }
        Ok(())
    }

    // ---- JSON -----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        // mode_param carries the regime's primary knob (OC factor, DL
        // deadline, async buffer size); mode_staleness is async-only.
        let (mode, mode_param, mode_staleness) = match self.mode {
            RoundMode::OverCommit { factor } => ("oc", factor, None),
            RoundMode::Deadline { deadline } => ("dl", deadline, None),
            RoundMode::Async { buffer_k, max_staleness } => {
                ("async", buffer_k as f64, max_staleness)
            }
        };
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("variant", Json::Str(self.variant.clone())),
            ("total_learners", num(self.total_learners as f64)),
            ("rounds", num(self.rounds as f64)),
            ("target_participants", num(self.target_participants as f64)),
            ("mode", Json::Str(mode.into())),
            ("mode_param", num(mode_param)),
            (
                "mode_staleness",
                mode_staleness.map(|t| num(t as f64)).unwrap_or(Json::Null),
            ),
            (
                "avail",
                Json::Str(match self.avail {
                    AvailMode::AllAvail => "all".into(),
                    AvailMode::DynAvail => "dyn".into(),
                }),
            ),
            ("selector", Json::Str(self.selector.clone())),
            ("use_saa", Json::Bool(self.use_saa)),
            ("scaling", Json::Str(self.scaling.label().into())),
            (
                "staleness_threshold",
                self.staleness_threshold.map(|t| num(t as f64)).unwrap_or(Json::Null),
            ),
            ("apt", Json::Bool(self.apt)),
            ("apt_alpha", num(self.apt_alpha)),
            ("server_opt", Json::Str(self.server_opt.clone())),
            ("lr", num(self.lr as f64)),
            ("local_epochs", num(self.local_epochs as f64)),
            ("partition", Json::Str(self.partition.label())),
            ("mean_samples", num(self.mean_samples as f64)),
            (
                "hardware",
                Json::Str(
                    match self.hardware {
                        HardwareScenario::Hs1 => "hs1",
                        HardwareScenario::Hs2 => "hs2",
                        HardwareScenario::Hs3 => "hs3",
                        HardwareScenario::Hs4 => "hs4",
                    }
                    .into(),
                ),
            ),
            ("safa_target_ratio", num(self.safa_target_ratio)),
            ("oracle", Json::Bool(self.oracle)),
            ("min_round_duration", num(self.min_round_duration)),
            ("cooldown_rounds", num(self.cooldown_rounds as f64)),
            ("eval_every", num(self.eval_every as f64)),
            ("test_per_class", num(self.test_per_class as f64)),
            ("seed", num(self.seed as f64)),
            ("workers", num(self.workers as f64)),
            ("train_workers", num(self.train_workers as f64)),
            ("coord_shards", num(self.coord_shards as f64)),
            ("faults", self.faults.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ExpConfig> {
        let d = ExpConfig::default();
        let gs = |k: &str, dflt: &str| -> String {
            j.get(k).and_then(|v| v.as_str()).unwrap_or(dflt).to_string()
        };
        let gu = |k: &str, dflt: usize| j.get(k).and_then(|v| v.as_usize()).unwrap_or(dflt);
        let gf = |k: &str, dflt: f64| j.get(k).and_then(|v| v.as_f64()).unwrap_or(dflt);
        let gb = |k: &str, dflt: bool| j.get(k).and_then(|v| v.as_bool()).unwrap_or(dflt);

        let mode = match gs("mode", "oc").as_str() {
            "oc" => RoundMode::OverCommit { factor: gf("mode_param", 1.3) },
            "dl" => RoundMode::Deadline { deadline: gf("mode_param", 100.0) },
            "async" => RoundMode::Async {
                buffer_k: gf("mode_param", 10.0) as usize,
                max_staleness: j.get("mode_staleness").and_then(|v| v.as_usize()),
            },
            m => return Err(anyhow!("unknown mode '{m}'")),
        };
        let avail = match gs("avail", "dyn").as_str() {
            "all" => AvailMode::AllAvail,
            "dyn" => AvailMode::DynAvail,
            a => return Err(anyhow!("unknown avail '{a}'")),
        };
        let partition = PartitionScheme::parse(&gs("partition", "iid"))
            .ok_or_else(|| anyhow!("unknown partition"))?;
        let scaling = ScalingRule::parse(&gs("scaling", "relay"))
            .ok_or_else(|| anyhow!("unknown scaling"))?;
        let hardware = HardwareScenario::parse(&gs("hardware", "hs1"))
            .ok_or_else(|| anyhow!("unknown hardware scenario"))?;
        let cfg = ExpConfig {
            label: gs("label", ""),
            variant: gs("variant", &d.variant),
            total_learners: gu("total_learners", d.total_learners),
            rounds: gu("rounds", d.rounds),
            target_participants: gu("target_participants", d.target_participants),
            mode,
            avail,
            selector: gs("selector", &d.selector),
            use_saa: gb("use_saa", d.use_saa),
            scaling,
            staleness_threshold: j
                .get("staleness_threshold")
                .and_then(|v| v.as_usize()),
            apt: gb("apt", d.apt),
            apt_alpha: gf("apt_alpha", d.apt_alpha),
            server_opt: gs("server_opt", &d.server_opt),
            lr: gf("lr", d.lr as f64) as f32,
            local_epochs: gu("local_epochs", d.local_epochs),
            partition,
            mean_samples: gu("mean_samples", d.mean_samples),
            hardware,
            safa_target_ratio: gf("safa_target_ratio", d.safa_target_ratio),
            oracle: gb("oracle", d.oracle),
            min_round_duration: gf("min_round_duration", d.min_round_duration),
            cooldown_rounds: gu("cooldown_rounds", d.cooldown_rounds),
            eval_every: gu("eval_every", d.eval_every),
            test_per_class: gu("test_per_class", d.test_per_class),
            seed: gf("seed", d.seed as f64) as u64,
            workers: gu("workers", d.workers),
            train_workers: gu("train_workers", d.train_workers),
            coord_shards: gu("coord_shards", d.coord_shards),
            faults: j.get("faults").map(FaultConfig::from_json).unwrap_or_default(),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<ExpConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }
}

/// Benchmark presets mirroring paper Table 1 (scaled: DESIGN.md §2).
pub fn preset(benchmark: &str) -> Result<ExpConfig> {
    let mut c = ExpConfig::default();
    match benchmark {
        "speech" => {
            c.variant = "speech".into();
            c.lr = 0.05;
            c.local_epochs = 1;
            c.server_opt = "yogi".into();
        }
        "cifar" => {
            c.variant = "cifar".into();
            c.lr = 0.05;
            c.local_epochs = 1;
            c.server_opt = "fedavg".into(); // paper: FedAvg for CIFAR10
        }
        "openimage" => {
            c.variant = "openimage".into();
            c.lr = 0.05;
            c.local_epochs = 2;
            c.server_opt = "yogi".into();
        }
        "nlp" => {
            c.variant = "nlp".into();
            c.lr = 0.02;
            c.local_epochs = 2;
            c.server_opt = "yogi".into();
        }
        "tiny" => {
            c.variant = "tiny".into();
            c.lr = 0.1;
            c.mean_samples = 20;
            c.test_per_class = 10;
        }
        other => return Err(anyhow!("unknown benchmark preset '{other}'")),
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::LabelSkew;

    #[test]
    fn default_validates() {
        ExpConfig::default().validate().unwrap();
    }

    #[test]
    fn relay_builder_sets_modules() {
        let c = ExpConfig::default().relay();
        assert_eq!(c.selector, "priority");
        assert!(c.use_saa);
        assert!(c.apt);
        assert_eq!(c.scaling.label(), "relay");
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut c = ExpConfig::default().relay().with_label("x");
        c.mode = RoundMode::Deadline { deadline: 100.0 };
        c.avail = AvailMode::AllAvail;
        c.staleness_threshold = Some(5);
        c.partition = PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Zipf };
        c.hardware = HardwareScenario::Hs3;
        c.oracle = true;
        c.train_workers = 5;
        c.coord_shards = 7;
        c.faults = FaultConfig {
            flap: 0.125,
            crash: 0.25,
            delay_secs: 64.0,
            fault_seed: 77,
            ..Default::default()
        };
        let j = c.to_json();
        let c2 = ExpConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(c2.label, "x");
        assert_eq!(c2.mode, RoundMode::Deadline { deadline: 100.0 });
        assert_eq!(c2.avail, AvailMode::AllAvail);
        assert_eq!(c2.staleness_threshold, Some(5));
        assert_eq!(c2.partition.label(), "label-zipf");
        assert_eq!(c2.hardware, HardwareScenario::Hs3);
        assert!(c2.oracle);
        assert_eq!(c2.selector, "priority");
        assert_eq!(c2.faults, c.faults);
        assert_eq!(c2.train_workers, 5);
        assert_eq!(c2.coord_shards, 7);
    }

    #[test]
    fn configs_without_train_workers_key_inherit_workers() {
        // pre-train-pool config files (no "train_workers" key) load as 0 =
        // inherit `workers`, which is the pre-PR behavior bit-for-bit
        let parsed = Json::parse(r#"{"mode": "oc", "workers": 3}"#).unwrap();
        let c = ExpConfig::from_json(&parsed).unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.train_workers, 0);
    }

    #[test]
    fn configs_without_coord_shards_key_autodetect() {
        // pre-sharded-coordination config files (no "coord_shards" key)
        // load as 0 = autodetect, which is byte-identical to any other K
        // by the shard-invariance contract
        let parsed = Json::parse(r#"{"mode": "oc", "workers": 3}"#).unwrap();
        let c = ExpConfig::from_json(&parsed).unwrap();
        assert_eq!(c.coord_shards, 0);
    }

    #[test]
    fn configs_without_faults_key_load_as_fault_free() {
        // a pre-fault-layer config file (no "faults" object) loads all-off
        let parsed = Json::parse(r#"{"mode": "oc", "selector": "oort"}"#).unwrap();
        let mut c = ExpConfig::from_json(&parsed).unwrap();
        assert!(!c.faults.is_active());
        assert_eq!(c.selector, "oort");
        c.faults.crash = 1.5;
        assert!(c.validate().is_err(), "bad fault rates must be rejected");
    }

    #[test]
    fn async_json_roundtrip() {
        let mut c = ExpConfig::default().with_label("async");
        c.mode = RoundMode::Async { buffer_k: 7, max_staleness: Some(3) };
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        let c2 = ExpConfig::from_json(&j).unwrap();
        assert_eq!(c2.mode, RoundMode::Async { buffer_k: 7, max_staleness: Some(3) });
        assert_eq!(c2.mode.label(), "ASYNC");

        c.mode = RoundMode::Async { buffer_k: 1, max_staleness: None };
        let j = Json::parse(&c.to_json().to_string()).unwrap();
        let c2 = ExpConfig::from_json(&j).unwrap();
        assert_eq!(c2.mode, RoundMode::Async { buffer_k: 1, max_staleness: None });
    }

    #[test]
    fn rejects_bad_async_configs() {
        let mut c = ExpConfig::default();
        c.mode = RoundMode::Async { buffer_k: 0, max_staleness: None };
        assert!(c.validate().is_err());
        let mut c = ExpConfig::default();
        c.mode = RoundMode::Async { buffer_k: 4, max_staleness: Some(2) };
        c.oracle = true;
        assert!(c.validate().is_err());
        c.oracle = false;
        c.validate().unwrap();
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = ExpConfig::default();
        c.target_participants = 0;
        assert!(c.validate().is_err());
        let mut c = ExpConfig::default();
        c.selector = "nope".into();
        assert!(c.validate().is_err());
        let mut c = ExpConfig::default();
        c.mode = RoundMode::OverCommit { factor: 0.5 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn presets_follow_table1() {
        assert_eq!(preset("cifar").unwrap().server_opt, "fedavg");
        assert_eq!(preset("speech").unwrap().server_opt, "yogi");
        assert!(preset("imagenet").is_err());
    }
}
