//! Integration: the AOT bridge end-to-end. Loads `artifacts/*.hlo.txt` on
//! the PJRT CPU client and cross-checks every computation against the
//! pure-rust native mirror. Skips (with a note) if `make artifacts` hasn't
//! been run.

use relay::runtime::{Backend, Executor, Manifest, NativeExecutor, PjrtExecutor};
use relay::util::rng::Rng;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn load_tiny() -> Option<PjrtExecutor> {
    let m = Manifest::load(artifacts_dir()).ok()?;
    Some(PjrtExecutor::load(&m, "tiny").expect("artifacts exist but failed to load"))
}

macro_rules! require_artifacts {
    () => {
        match load_tiny() {
            Some(e) => e,
            None => {
                eprintln!("SKIP: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn batch(v: &relay::runtime::VariantInfo, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..v.batch * v.input_dim).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..v.batch).map(|_| rng.below(v.num_classes) as i32).collect();
    (x, y, vec![1.0; v.batch])
}

#[test]
fn init_params_deterministic_and_sized() {
    let e = require_artifacts!();
    let p = e.init_params(42).unwrap();
    assert_eq!(p.len(), e.variant().num_params);
    assert_eq!(p, e.init_params(42).unwrap());
    assert_ne!(p, e.init_params(43).unwrap());
    assert!(p.iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_matches_native_mirror() {
    let e = require_artifacts!();
    let native = NativeExecutor::new(e.variant().clone());
    let params = e.init_params(7).unwrap();
    let (x, y, mask) = batch(e.variant(), 1);

    let a = e.train_step(&params, &x, &y, &mask, 0.05).unwrap();
    let b = native.train_step(&params, &x, &y, &mask, 0.05).unwrap();
    assert!((a.loss - b.loss).abs() < 1e-4, "loss {} vs {}", a.loss, b.loss);
    assert_eq!(a.correct, b.correct);
    let max_diff = a
        .params
        .iter()
        .zip(&b.params)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-4, "param divergence {max_diff}");
}

#[test]
fn eval_matches_native_mirror() {
    let e = require_artifacts!();
    let native = NativeExecutor::new(e.variant().clone());
    let params = e.init_params(3).unwrap();
    let (x, y, mask) = batch(e.variant(), 2);
    let (la, ca) = e.eval_batch(&params, &x, &y, &mask).unwrap();
    let (lb, cb) = native.eval_batch(&params, &x, &y, &mask).unwrap();
    assert!((la - lb).abs() < 1e-4, "{la} vs {lb}");
    assert_eq!(ca, cb);
}

#[test]
fn training_descends_through_pjrt() {
    let e = require_artifacts!();
    let mut params = e.init_params(0).unwrap();
    let (x, y, mask) = batch(e.variant(), 5);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..60 {
        let out = e.train_step(&params, &x, &y, &mask, 0.1).unwrap();
        params = out.params;
        first.get_or_insert(out.loss);
        last = out.loss;
    }
    let first = first.unwrap();
    assert!(last < first * 0.5, "no descent through HLO: {first} -> {last}");
}

#[test]
fn agg_kernels_match_native() {
    let e = require_artifacts!();
    let native = NativeExecutor::new(e.variant().clone());
    let p = e.variant().num_params;
    let mut rng = Rng::new(11);
    let rows: Vec<Vec<f32>> =
        (0..3).map(|_| (0..p).map(|_| rng.normal() as f32).collect()).collect();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let w = [0.5f32, 0.25, 0.1];

    let a = e.agg_combine(&refs, &w).unwrap();
    let b = native.agg_combine(&refs, &w).unwrap();
    let max_diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_diff < 1e-4, "agg divergence {max_diff}");

    let fresh: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
    let da = e.agg_dev(&fresh, &refs[..2]).unwrap();
    let db = native.agg_dev(&fresh, &refs[..2]).unwrap();
    assert_eq!(da.len(), 3);
    for (x, y) in da.iter().zip(&db) {
        let rel = (x - y).abs() / y.abs().max(1.0);
        assert!(rel < 1e-4, "dev divergence {x} vs {y}");
    }
}

#[test]
fn masked_padding_rows_are_inert_through_pjrt() {
    let e = require_artifacts!();
    let v = e.variant().clone();
    let params = e.init_params(1).unwrap();
    let (mut x, y, _) = batch(&v, 9);
    let mut mask = vec![1.0f32; v.batch];
    mask[v.batch - 1] = 0.0;
    let o1 = e.train_step(&params, &x, &y, &mask, 0.05).unwrap();
    for i in 0..v.input_dim {
        x[(v.batch - 1) * v.input_dim + i] = 1e3;
    }
    let o2 = e.train_step(&params, &x, &y, &mask, 0.05).unwrap();
    assert!((o1.loss - o2.loss).abs() < 1e-5);
}

#[test]
fn load_executor_backend_selection() {
    if Manifest::load(artifacts_dir()).is_err() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let e = relay::runtime::load_executor(&artifacts_dir(), "tiny", Backend::Native).unwrap();
    assert_eq!(e.variant().name, "tiny");
    let e = relay::runtime::load_executor(&artifacts_dir(), "tiny", Backend::Pjrt).unwrap();
    assert_eq!(e.variant().num_params, 172);
}
