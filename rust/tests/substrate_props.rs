//! Property tests over the sim/trace substrate (via `relay::util::prop`):
//! delivery-queue determinism under `deliver_at` ties, trace well-formedness
//! across randomized generator configs, and lazy==eager trace equivalence.

use relay::sim::DeliveryQueue;
use relay::trace::{LazyTraceSet, TraceConfig, TraceSet, WEEK};
use relay::util::prop::{prop_assert, prop_check, PropResult};
use relay::util::rng::Rng;

fn random_trace_config(rng: &mut Rng) -> TraceConfig {
    TraceConfig {
        median_session: rng.uniform(60.0, 1200.0),
        session_sigma: rng.uniform(0.4, 1.5),
        overnight_frac: rng.f64() * 0.3,
        peak_gap: rng.uniform(1800.0, 6.0 * 3600.0),
        diurnal_strength: rng.uniform(1.0, 8.0),
        phase_jitter: rng.uniform(600.0, 4.0 * 3600.0),
        nightly_block: if rng.bool(0.4) {
            Some((rng.uniform(3600.0, 6.0 * 3600.0), rng.uniform(60.0, 900.0)))
        } else {
            None
        },
    }
}

#[test]
fn delivery_queue_deterministic_under_ties() {
    prop_check(100, 0x71E5, |rng| {
        let n = rng.range(1, 40);
        // deliver_at drawn from a tiny discrete set so ties are the norm
        let times: Vec<f64> = (0..n).map(|_| rng.below(4) as f64).collect();
        let mut q1 = DeliveryQueue::default();
        let mut q2 = DeliveryQueue::default();
        for (i, &t) in times.iter().enumerate() {
            q1.push(t, i);
            q2.push(t, i);
        }
        let mut d1: Vec<(i64, usize)> = Vec::new();
        let mut d2: Vec<(i64, usize)> = Vec::new();
        for cut in [0.0, 1.0, 3.0] {
            d1.extend(q1.due(cut).into_iter().map(|p| (p.deliver_at as i64, p.item)));
            d2.extend(q2.due(cut).into_iter().map(|p| (p.deliver_at as i64, p.item)));
        }
        // identical push sequences must drain in an identical order, even
        // among equal deliver_at ties (the coordinator's stale-update
        // aggregation order — and therefore the model — depends on it)
        prop_assert(d1 == d2, format!("tie order diverged: {d1:?} vs {d2:?}"))?;
        prop_assert(
            d1.windows(2).all(|w| w[0].0 <= w[1].0),
            format!("deliveries out of time order: {d1:?}"),
        )?;
        prop_assert(
            d1.len() == times.len(),
            format!("drained {} of {} due items", d1.len(), times.len()),
        )?;
        prop_assert(q1.is_empty() && q2.is_empty(), "queue not fully drained")
    });
}

#[test]
fn generated_traces_sorted_nonoverlapping_within_week() {
    prop_check(30, 0x7ACE, |rng| {
        let config = random_trace_config(rng);
        let n = rng.range(1, 12);
        let t = TraceSet::generate(n, rng.next_u64(), config);
        for (l, s) in t.sessions.iter().enumerate() {
            for w in s.windows(2) {
                prop_assert(
                    w[0].1 <= w[1].0,
                    format!("learner {l}: overlapping sessions {w:?}"),
                )?;
            }
            for &(a, b) in s {
                prop_assert(a < b, format!("learner {l}: empty session ({a},{b})"))?;
                prop_assert(
                    a >= 0.0 && b <= WEEK + 1e-9,
                    format!("learner {l}: session outside week ({a},{b})"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn lazy_matches_eager_for_random_populations() {
    prop_check(20, 0x1A27, |rng| {
        let config = random_trace_config(rng);
        let n = rng.range(1, 20);
        let seed = rng.next_u64();
        let eager = TraceSet::generate(n, seed, config);
        let lazy = LazyTraceSet::new(n, seed, config);
        prop_assert(lazy.materialized() == 0, "lazy generated traces up front")?;
        for l in 0..n {
            prop_assert(
                eager.sessions[l].as_slice() == lazy.sessions(l),
                format!("learner {l} diverged (seed {seed})"),
            )?;
        }
        prop_assert(lazy.materialized() == n, "materialized count wrong after touch")
    });
}
