//! Property tests over the sim/trace substrate (via `relay::util::prop`):
//! delivery-queue determinism under `deliver_at` ties, event-kernel FIFO
//! ordering among simultaneous events, async-regime accounting invariants,
//! trace well-formedness across randomized generator configs, and
//! lazy==eager trace equivalence.

use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::run_experiment;
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::sim::{DeliveryQueue, EventClass, EventKernel};
use relay::trace::{LazyTraceSet, TraceConfig, TraceSet, WEEK};
use relay::util::prop::{prop_assert, prop_check, PropResult};
use relay::util::rng::Rng;

fn random_trace_config(rng: &mut Rng) -> TraceConfig {
    TraceConfig {
        median_session: rng.uniform(60.0, 1200.0),
        session_sigma: rng.uniform(0.4, 1.5),
        overnight_frac: rng.f64() * 0.3,
        peak_gap: rng.uniform(1800.0, 6.0 * 3600.0),
        diurnal_strength: rng.uniform(1.0, 8.0),
        phase_jitter: rng.uniform(600.0, 4.0 * 3600.0),
        nightly_block: if rng.bool(0.4) {
            Some((rng.uniform(3600.0, 6.0 * 3600.0), rng.uniform(60.0, 900.0)))
        } else {
            None
        },
    }
}

#[test]
fn delivery_queue_deterministic_under_ties() {
    prop_check(100, 0x71E5, |rng| {
        let n = rng.range(1, 40);
        // deliver_at drawn from a tiny discrete set so ties are the norm
        let times: Vec<f64> = (0..n).map(|_| rng.below(4) as f64).collect();
        let mut q1 = DeliveryQueue::default();
        let mut q2 = DeliveryQueue::default();
        for (i, &t) in times.iter().enumerate() {
            q1.push(t, i);
            q2.push(t, i);
        }
        let mut d1: Vec<(i64, usize)> = Vec::new();
        let mut d2: Vec<(i64, usize)> = Vec::new();
        for cut in [0.0, 1.0, 3.0] {
            d1.extend(q1.due(cut).into_iter().map(|p| (p.deliver_at as i64, p.item)));
            d2.extend(q2.due(cut).into_iter().map(|p| (p.deliver_at as i64, p.item)));
        }
        // identical push sequences must drain in an identical order, even
        // among equal deliver_at ties (the coordinator's stale-update
        // aggregation order — and therefore the model — depends on it)
        prop_assert(d1 == d2, format!("tie order diverged: {d1:?} vs {d2:?}"))?;
        prop_assert(
            d1.windows(2).all(|w| w[0].0 <= w[1].0),
            format!("deliveries out of time order: {d1:?}"),
        )?;
        prop_assert(
            d1.len() == times.len(),
            format!("drained {} of {} due items", d1.len(), times.len()),
        )?;
        prop_assert(q1.is_empty() && q2.is_empty(), "queue not fully drained")
    });
}

#[test]
fn kernel_simultaneous_events_pop_in_fifo_order() {
    // Simultaneous events must pop in deterministic (time, class, FIFO)
    // order no matter how insertions interleave: the oracle is a stable
    // sort by (time, class), which preserves insertion order among ties.
    prop_check(100, 0xF1F0, |rng| {
        let n = rng.range(1, 50);
        let classes = [
            EventClass::Delivery,
            EventClass::Departure,
            EventClass::Eval,
            EventClass::CheckIn,
        ];
        // times drawn from a tiny discrete set so ties are the norm
        let evs: Vec<(f64, EventClass, usize)> = (0..n)
            .map(|i| (rng.below(3) as f64, classes[rng.below(4)], i))
            .collect();
        let mut k = EventKernel::default();
        for &(t, c, i) in &evs {
            k.schedule(t, c, i);
        }
        let popped: Vec<(f64, EventClass, usize)> = k
            .pop_due(3.0)
            .into_iter()
            .map(|e| (e.at, e.class, e.payload))
            .collect();
        let mut expected = evs.clone();
        expected.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        prop_assert(
            popped == expected,
            format!("kernel order diverged:\n  got      {popped:?}\n  expected {expected:?}"),
        )?;
        prop_assert(k.is_empty(), "kernel not fully drained")
    });
}

#[test]
fn async_accounting_invariants_hold_for_random_configs() {
    // The async engine's per-event accounting: at every merge record,
    // aggregated + wasted + in-flight device-seconds must sum to spent,
    // and the concurrency integral must stay within [0, target].
    prop_check(8, 0xA51C, |rng| {
        let selectors = ["random", "priority", "oort"];
        let cfg = ExpConfig {
            variant: "tiny".into(),
            total_learners: rng.range(8, 24),
            rounds: rng.range(2, 6),
            target_participants: rng.range(2, 6),
            mode: RoundMode::Async {
                buffer_k: rng.range(1, 5),
                max_staleness: if rng.bool(0.5) { Some(rng.range(0, 4)) } else { None },
            },
            avail: if rng.bool(0.5) { AvailMode::AllAvail } else { AvailMode::DynAvail },
            selector: selectors[rng.below(3)].into(),
            mean_samples: 8,
            test_per_class: 2,
            eval_every: 2,
            cooldown_rounds: 1,
            lr: 0.1,
            seed: rng.next_u64() % 10_000,
            ..Default::default()
        };
        let exec: Arc<dyn Executor> =
            Arc::new(NativeExecutor::new(builtin_variant("tiny")));
        let r = run_experiment(cfg.clone(), exec).map_err(|e| format!("run failed: {e:#}"))?;
        prop_assert(
            r.rounds.len() == cfg.rounds,
            format!("{} records for {} rounds", r.rounds.len(), cfg.rounds),
        )?;
        for rec in &r.rounds {
            let agg = rec
                .cum_aggregated_secs
                .ok_or("async record missing cum_aggregated_secs")?;
            let inflight = rec.in_flight_secs.ok_or("async record missing in_flight_secs")?;
            let conc = rec.mean_concurrency.ok_or("async record missing mean_concurrency")?;
            prop_assert(
                inflight >= -1e-9,
                format!("negative in-flight {inflight} at round {}", rec.round),
            )?;
            prop_assert(agg >= 0.0, format!("negative aggregated {agg}"))?;
            let spent = rec.cum_resource_secs;
            let closed = agg + rec.cum_waste_secs + inflight;
            prop_assert(
                (spent - closed).abs() <= 1e-6 * spent.max(1.0),
                format!(
                    "round {}: spent {spent} != aggregated {agg} + wasted {} + in-flight {inflight}",
                    rec.round, rec.cum_waste_secs
                ),
            )?;
            prop_assert(
                (0.0..=cfg.target_participants as f64 + 1e-9).contains(&conc),
                format!("round {}: mean concurrency {conc} outside [0, target]", rec.round),
            )?;
        }
        Ok(())
    });
}

#[test]
fn generated_traces_sorted_nonoverlapping_within_week() {
    prop_check(30, 0x7ACE, |rng| {
        let config = random_trace_config(rng);
        let n = rng.range(1, 12);
        let t = TraceSet::generate(n, rng.next_u64(), config);
        for (l, s) in t.sessions.iter().enumerate() {
            for w in s.windows(2) {
                prop_assert(
                    w[0].1 <= w[1].0,
                    format!("learner {l}: overlapping sessions {w:?}"),
                )?;
            }
            for &(a, b) in s {
                prop_assert(a < b, format!("learner {l}: empty session ({a},{b})"))?;
                prop_assert(
                    a >= 0.0 && b <= WEEK + 1e-9,
                    format!("learner {l}: session outside week ({a},{b})"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn lazy_matches_eager_for_random_populations() {
    prop_check(20, 0x1A27, |rng| {
        let config = random_trace_config(rng);
        let n = rng.range(1, 20);
        let seed = rng.next_u64();
        let eager = TraceSet::generate(n, seed, config);
        let lazy = LazyTraceSet::new(n, seed, config);
        prop_assert(lazy.materialized() == 0, "lazy generated traces up front")?;
        for l in 0..n {
            prop_assert(
                eager.sessions[l].as_slice() == lazy.sessions(l),
                format!("learner {l} diverged (seed {seed})"),
            )?;
        }
        prop_assert(lazy.materialized() == n, "materialized count wrong after touch")
    });
}
