//! Regression suite: every `ExperimentResult` JSON produced by a sweep
//! grid must parse with `util::json` — no non-finite float (the seed's
//! `train_loss: NaN` on nothing-trained rounds) may ever leak into output
//! again. The grid below deliberately includes cells whose rounds all fail
//! (starved cooldowns) and async cells with burned slots, the two paths
//! that used to emit NaN/0.0 placeholders.

use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::data::partition::PartitionScheme;
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::sweep::{run_grid_results, GridSpec, SweepOpts};
use relay::util::json::Json;

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

fn check_grid(spec: &GridSpec) {
    let (cells, results) =
        run_grid_results(spec, exec(), &SweepOpts { workers: 2, progress: false }).unwrap();
    assert_eq!(results.len(), spec.total_runs());
    let per_cell = spec.seeds.len();
    for (i, r) in results.iter().enumerate() {
        let cell = &cells[i / per_cell].label;
        let text = r.to_json().to_string();
        let parsed = Json::parse(&text).unwrap_or_else(|e| {
            panic!("cell '{cell}' run {i}: sweep produced unparseable JSON ({e}): {text}")
        });
        // the per-round records survive the round-trip with the expected
        // shape: train_loss is a number or null, never a bare NaN token
        let rounds = parsed.get("rounds").and_then(|x| x.as_arr()).unwrap_or_else(|| {
            panic!("cell '{cell}' run {i}: missing rounds array")
        });
        assert_eq!(rounds.len(), r.rounds.len(), "cell '{cell}' run {i}");
        for (rec, jr) in r.rounds.iter().zip(rounds) {
            let tl = jr.get("train_loss").expect("train_loss key present");
            match rec.train_loss {
                Some(v) => {
                    assert!(v.is_finite(), "cell '{cell}': non-finite train_loss {v}");
                    assert_eq!(tl.as_f64(), Some(v), "cell '{cell}'");
                }
                None => assert_eq!(tl, &Json::Null, "cell '{cell}'"),
            }
        }
    }
}

/// OC/DL grid including a starved cell: 4 learners, everyone selected in
/// round 0, then a long cooldown fails several rounds in a row — the
/// nothing-trained path that used to serialize `train_loss: NaN`.
#[test]
fn sync_grid_results_all_parse() {
    let base = ExpConfig {
        variant: "tiny".into(),
        total_learners: 4,
        rounds: 4,
        target_participants: 4,
        cooldown_rounds: 6,
        mean_samples: 6,
        test_per_class: 2,
        eval_every: 2,
        min_round_duration: 0.0,
        lr: 0.1,
        ..Default::default()
    };
    let spec = GridSpec {
        label: "json-valid-sync".into(),
        selectors: vec!["random".into(), "safa".into()],
        modes: vec![
            RoundMode::OverCommit { factor: 1.3 },
            RoundMode::Deadline { deadline: 40.0 },
        ],
        avails: vec![AvailMode::AllAvail, AvailMode::DynAvail],
        partitions: vec![PartitionScheme::UniformIid],
        coord_shards: vec![0],
        jobs: vec![1],
        seeds: vec![1, 1001],
        base,
    };
    check_grid(&spec);
}

/// Async grid with tiny DynAvail populations: burned slots produce failed
/// merge records (train_loss null) that must stay valid JSON.
#[test]
fn async_grid_results_all_parse() {
    let base = ExpConfig {
        variant: "tiny".into(),
        total_learners: 8,
        rounds: 5,
        target_participants: 3,
        cooldown_rounds: 2,
        mean_samples: 6,
        test_per_class: 2,
        eval_every: 2,
        lr: 0.1,
        ..Default::default()
    };
    let spec = GridSpec {
        label: "json-valid-async".into(),
        selectors: vec!["random".into(), "priority".into()],
        modes: vec![RoundMode::Async { buffer_k: 2, max_staleness: Some(3) }],
        avails: vec![AvailMode::DynAvail],
        partitions: vec![PartitionScheme::UniformIid],
        coord_shards: vec![0],
        jobs: vec![1],
        seeds: vec![7, 1007],
        base,
    };
    check_grid(&spec);
}
