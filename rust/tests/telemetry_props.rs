//! Observability equivalence properties: the streaming watcher must be an
//! *exact* alternative lens over a run, never a second implementation.
//!
//! * feeding a run's event log through `TelemetryStream` yields the
//!   byte-identical `ExperimentResult` that batch `replay()` derives, for
//!   every golden-matrix cell (sync OC/DL and async, all selectors);
//! * running an experiment with the live observer attached produces a
//!   result byte-identical to the same run without it;
//! * `watch_dir --once` over an on-disk log exports the same bytes as the
//!   replay oracle;
//! * the per-cause waste gauges always sum to the reducer's waste total.

use std::path::PathBuf;
use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::{run_experiment, run_experiment_logged, run_experiment_observed};
use relay::runlog::{decode_segments, replay, DirSink, MemSink};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::telemetry::{watch_dir, SharedStream, TelemetryStream, WatchOpts};

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// The same straggler-rich DynAvail cell the golden-baseline suite pins.
fn cell_cfg(selector: &str, mode: RoundMode) -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 14,
        rounds: 5,
        target_participants: 4,
        mode,
        avail: AvailMode::DynAvail,
        selector: selector.into(),
        use_saa: true,
        staleness_threshold: Some(3),
        mean_samples: 8,
        test_per_class: 4,
        eval_every: 2,
        cooldown_rounds: 1,
        min_round_duration: 0.0,
        lr: 0.1,
        ..Default::default()
    }
}

fn modes() -> Vec<(&'static str, RoundMode)> {
    vec![
        ("oc", RoundMode::OverCommit { factor: 1.3 }),
        ("dl", RoundMode::Deadline { deadline: 2.0 }),
        ("async", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }),
    ]
}

#[test]
fn watcher_snapshot_matches_replay_on_every_golden_matrix_cell() {
    for selector in ["random", "oort", "priority", "safa"] {
        for (mode_name, mode) in modes() {
            let label = format!("telem-{selector}-{mode_name}");
            let mut cfg = cell_cfg(selector, mode);
            cfg.label = label.clone();
            let sink = MemSink::default();
            let engine = run_experiment_logged(cfg, exec(), Box::new(sink.clone()))
                .unwrap_or_else(|e| panic!("cell '{label}' failed: {e:#}"));
            let engine_bytes = engine.to_json().to_string();
            let (events, stats) = decode_segments(&sink.segments());
            assert!(stats.clean, "cell '{label}': dirty log: {:?}", stats.note);
            let replayed_bytes = replay(&events)
                .unwrap_or_else(|e| panic!("cell '{label}' replay failed: {e:#}"))
                .to_json()
                .to_string();
            let mut stream = TelemetryStream::new();
            for ev in &events {
                stream.step(ev);
            }
            assert!(stream.complete(), "cell '{label}': stream missed RunEnd");
            assert!(stream.error().is_none(), "cell '{label}': {:?}", stream.error());
            let streamed_bytes = stream
                .result()
                .unwrap_or_else(|e| panic!("cell '{label}' stream result failed: {e:#}"))
                .to_json()
                .to_string();
            assert_eq!(
                streamed_bytes, replayed_bytes,
                "cell '{label}': watcher final snapshot diverged from batch replay"
            );
            assert_eq!(
                streamed_bytes, engine_bytes,
                "cell '{label}': watcher final snapshot diverged from the engine"
            );
            // per-cause waste attribution telescopes to the reducer total
            let causes: f64 = stream
                .registry()
                .gauges_with_prefix("waste.")
                .map(|(_, v)| v)
                .sum();
            let wasted = stream.live().wasted;
            assert!(
                (causes - wasted).abs() <= 1e-9 * wasted.abs().max(1.0),
                "cell '{label}': per-cause waste {causes} != reducer total {wasted}"
            );
        }
    }
}

/// Attaching the in-process live observer must not perturb the result —
/// the `--live` non-perturbation guarantee, sync and async.
#[test]
fn live_observer_leaves_results_byte_identical() {
    for (mode_name, mode) in [
        ("dl", RoundMode::Deadline { deadline: 2.0 }),
        ("async", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }),
    ] {
        let mut cfg = cell_cfg("priority", mode);
        cfg.label = format!("live-{mode_name}");
        let plain = run_experiment(cfg.clone(), exec())
            .unwrap_or_else(|e| panic!("plain {mode_name} failed: {e:#}"));
        let shared = SharedStream::new();
        let observed = run_experiment_observed(cfg, exec(), shared.observer())
            .unwrap_or_else(|e| panic!("observed {mode_name} failed: {e:#}"));
        assert_eq!(
            observed.to_json().to_string(),
            plain.to_json().to_string(),
            "{mode_name}: live observer perturbed the result"
        );
        assert!(shared.complete(), "{mode_name}: observer missed RunEnd");
        let through_stream = shared
            .with(|s| s.result())
            .unwrap_or_else(|e| panic!("shared {mode_name} result failed: {e:#}"));
        assert_eq!(
            through_stream.to_json().to_string(),
            plain.to_json().to_string(),
            "{mode_name}: the observed stream's own result diverged"
        );
    }
}

/// `relay watch --once` over an on-disk log is the replay oracle in
/// another coat: same reducer, same bytes.
#[test]
fn watch_dir_once_matches_replay_over_a_dir_sink_log() {
    let dir: PathBuf = std::env::temp_dir().join(format!(
        "relay-telemetry-watchdir-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = cell_cfg("safa", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) });
    cfg.label = "watchdir".into();
    let sink = DirSink::create(&dir).expect("create log dir");
    let engine = run_experiment_logged(cfg, exec(), Box::new(sink)).expect("logged run");
    let mut out = Vec::new();
    let opts = WatchOpts { once: true, ..WatchOpts::default() };
    let stream = watch_dir(&dir, &opts, &mut out).expect("watch --once");
    assert!(stream.complete(), "one-shot watch must see the whole finished log");
    let watched = stream.result().expect("watched result").to_json().to_string();
    assert_eq!(
        watched,
        engine.to_json().to_string(),
        "watch --once diverged from the engine result"
    );
    let dashboard = String::from_utf8(out).expect("utf8 dashboard");
    assert!(dashboard.contains("complete"), "{dashboard}");
    let _ = std::fs::remove_dir_all(&dir);
}
