//! Property tests for the selection-index subsystem (`selection::index`)
//! and the indexed selector fast paths:
//!
//! * the sharded [`ScoreIndex`] must agree with a brute-force sorted-Vec
//!   model on randomized insert/remove/update sequences (top-k, rank,
//!   level queries, weighted sampling) and be shard-count invariant;
//! * every indexed `select_from` (oort / priority / safa / random) must be
//!   **element-for-element identical** to the materialized `select` over
//!   the ascending-id candidate list — same RNG draws — under eligibility
//!   churn, feedback, pacer re-keys, and probe time-bucket changes;
//! * the pipeline holds at scale: 20k-learner lazy DynAvail cells run
//!   through the indexed paths, deterministic and byte-identical to the
//!   frozen materializing reference on the sync grid.

use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::{run_experiment, run_reference_experiment};
use relay::population::CandidateSet;
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::selection::index::ScoreIndex;
use relay::selection::{by_name, Candidate, ProbeSource, SelectPool, SelectionCtx, SlotSig};
use relay::util::prop::{prop_assert, prop_check};
use relay::util::rng::Rng;

/// Brute-force model entry list sorted by the index's global order.
fn sorted_model(model: &[Option<f64>]) -> Vec<(usize, f64)> {
    let mut v: Vec<(usize, f64)> = model
        .iter()
        .enumerate()
        .filter_map(|(id, s)| s.map(|s| (id, s)))
        .collect();
    v.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    v
}

#[test]
fn score_index_matches_sorted_vec_model() {
    prop_check(40, 0x51DE, |rng| {
        let n = rng.range(1, 300);
        let num_shards = rng.range(1, 10);
        let mut idx = ScoreIndex::with_shards(n, num_shards);
        let mut model: Vec<Option<f64>> = vec![None; n];
        for _ in 0..rng.range(1, 600) {
            let id = rng.below(n);
            if rng.bool(0.6) {
                // multiples of 0.5: exactly representable, so float sums
                // are association-free and the sampling model is exact
                let score = rng.below(8) as f64 * 0.5;
                idx.insert(id, score);
                model[id] = Some(score);
            } else {
                idx.remove(id);
                model[id] = None;
            }
        }
        let sorted = sorted_model(&model);
        prop_assert(idx.len() == sorted.len(), "len diverged")?;
        prop_assert(idx.to_sorted_vec() == sorted, "sorted contents diverged")?;

        // top-k: score descending, id ascending within a level
        let k = rng.range(0, 25);
        let mut top = Vec::new();
        idx.top_k_desc(k, |id, s| top.push((id, s)));
        let want_top: Vec<(usize, f64)> = {
            let mut v = sorted.clone();
            v.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            v.truncate(k.min(v.len()));
            v
        };
        prop_assert(top == want_top, format!("top-{k} diverged"))?;

        // rank + level queries
        for (r, &(id, _)) in sorted.iter().enumerate() {
            prop_assert(
                idx.rank_of(id) == Some(r),
                format!("rank_of({id}) = {:?} != {r}", idx.rank_of(id)),
            )?;
        }
        for level in 0..8 {
            let p = level as f64 * 0.5;
            prop_assert(
                idx.count_lt(p) == sorted.iter().filter(|e| e.1 < p).count(),
                "count_lt diverged",
            )?;
            let members: Vec<usize> =
                sorted.iter().filter(|e| e.1 == p).map(|e| e.0).collect();
            prop_assert(idx.level_len(p) == members.len(), "level_len diverged")?;
            for (i, &id) in members.iter().enumerate() {
                prop_assert(
                    idx.nth_in_level(p, i) == id,
                    format!("nth_in_level({p}, {i}) diverged"),
                )?;
            }
        }

        // weighted sampling: exact replay of the level walk over the global
        // ascending (score, id) order — the draw is a pure function of the
        // member set, independent of the shard layout
        let mut levels: Vec<(f64, Vec<usize>)> = Vec::new();
        for &(id, s) in &sorted {
            if let Some(last) = levels.last_mut() {
                if last.0 == s {
                    last.1.push(id);
                    continue;
                }
            }
            levels.push((s, vec![id]));
        }
        let total: f64 = {
            let mut acc = 0.0f64;
            for (p, ids) in &levels {
                if *p > 0.0 {
                    acc += *p * ids.len() as f64;
                }
            }
            acc
        };
        for _ in 0..3 {
            let seed = rng.next_u64();
            let got = idx.weighted_sample(&mut Rng::new(seed));
            let want = if total > 0.0 {
                let mut u = Rng::new(seed).f64() * total;
                let mut pick = None;
                for (p, ids) in &levels {
                    if !(*p > 0.0) {
                        continue;
                    }
                    let mass = *p * ids.len() as f64;
                    if u < mass {
                        pick = Some(ids[((u / *p) as usize).min(ids.len() - 1)]);
                        break;
                    }
                    u -= mass;
                }
                pick.or_else(|| {
                    levels
                        .iter()
                        .rev()
                        .find(|(p, _)| *p > 0.0)
                        .map(|(_, ids)| *ids.last().unwrap())
                })
            } else {
                None
            };
            prop_assert(got == want, format!("weighted_sample diverged (seed {seed})"))?;
            // and the 1-shard twin of the same member set draws the same id
            let mut single = ScoreIndex::with_shards(n, 1);
            for &(id, s) in &sorted {
                single.insert(id, s);
            }
            prop_assert(
                single.weighted_sample(&mut Rng::new(seed)) == got,
                format!("weighted_sample layout-variant (seed {seed}, {num_shards} shards)"),
            )?;
        }
        Ok(())
    });
}

/// The resolved ROADMAP follow-up, as a standalone property: the specific
/// weighted draw (not just its distribution) is byte-identical across shard
/// layouts, with identical RNG consumption.
#[test]
fn weighted_sample_is_shard_layout_invariant() {
    prop_check(30, 0x77AD, |rng| {
        let n = rng.range(1, 300);
        let mut entries: Vec<(usize, f64)> = Vec::new();
        for id in 0..n {
            if rng.bool(0.6) {
                entries.push((id, rng.below(7) as f64 * 0.25));
            }
        }
        let build = |shards: usize| {
            let mut idx = ScoreIndex::with_shards(n, shards);
            for &(id, s) in &entries {
                idx.insert(id, s);
            }
            idx
        };
        let a = build(1);
        let b = build(rng.range(2, 12));
        for _ in 0..5 {
            let seed = rng.next_u64();
            let mut ra = Rng::new(seed);
            let mut rb = Rng::new(seed);
            prop_assert(
                a.weighted_sample(&mut ra) == b.weighted_sample(&mut rb),
                format!("draw diverged across layouts (seed {seed})"),
            )?;
            prop_assert(
                ra.next_u64() == rb.next_u64(),
                "rng consumption diverged across layouts",
            )?;
        }
        Ok(())
    });
}

/// The priority selector's hour-bucket **delta-rebuild** (only learners
/// whose bin value changed are re-keyed) must be indistinguishable from a
/// from-scratch rebuild: same picks, same RNG draws, at every step of a
/// churning, time-advancing run that crosses many probe buckets.
#[test]
fn priority_bucket_delta_rebuild_matches_full_rebuild() {
    prop_check(10, 0xDE17A, |rng| {
        let n = rng.range(5, 80);
        let probes = GridProbes;
        let mut set = CandidateSet::new(n);
        let mut eligible = vec![false; n];
        let mut maintained = by_name("priority").unwrap();
        let mut now = 0.0f64;
        for step in 0..20 {
            now += rng.uniform(0.0, 7200.0); // frequent hour-bucket moves
            for _ in 0..rng.range(0, 6) {
                let id = rng.below(n);
                if eligible[id] {
                    eligible[id] = false;
                    set.remove(id);
                    maintained.on_ineligible(id);
                } else {
                    eligible[id] = true;
                    set.insert(id);
                    maintained.on_eligible(id);
                }
            }
            let target = rng.range(0, n + 2);
            let seed = rng.next_u64();
            let pool = SelectPool { set: &set, probes: &probes, mu: 80.0 };
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let a = maintained
                .select_from(&pool, step, now, target, &mut r1)
                .expect("priority is indexed");
            let mut fresh = by_name("priority").unwrap();
            let b = fresh
                .select_from(&pool, step, now, target, &mut r2)
                .expect("priority is indexed");
            prop_assert(a == b, format!("step {step}: delta-rebuild diverged"))?;
            prop_assert(r1.next_u64() == r2.next_u64(), "rng state diverged")?;
        }
        Ok(())
    });
}

#[test]
fn score_index_ranking_is_shard_count_invariant() {
    prop_check(30, 0x5AAD, |rng| {
        let n = rng.range(1, 250);
        // sequential draws: a filter/map closure pair sharing the rng would
        // be two simultaneous mutable borrows (E0499)
        let mut entries: Vec<(usize, f64)> = Vec::new();
        for id in 0..n {
            if rng.bool(0.5) {
                entries.push((id, rng.below(6) as f64 * 0.25));
            }
        }
        let build = |shards: usize| {
            let mut idx = ScoreIndex::with_shards(n, shards);
            for &(id, s) in &entries {
                idx.insert(id, s);
            }
            idx
        };
        let a = build(1);
        let b = build(rng.range(2, 12));
        prop_assert(a.to_sorted_vec() == b.to_sorted_vec(), "contents diverged")?;
        let k = rng.range(0, 20);
        let mut ta = Vec::new();
        let mut tb = Vec::new();
        a.top_k_desc(k, |id, s| ta.push((id, s)));
        b.top_k_desc(k, |id, s| tb.push((id, s)));
        prop_assert(ta == tb, "top-k diverged across shard counts")?;
        for &(id, _) in &entries {
            prop_assert(a.rank_of(id) == b.rank_of(id), "rank diverged")?;
        }
        Ok(())
    });
}

/// Probe source whose answers vary by (id, hour-of-now) on a coarse value
/// grid — plenty of exact ties (levels) and genuine time-bucket changes, so
/// the per-bucket probability trees exercise both the delta-apply and the
/// rebuild paths.
struct GridProbes;

impl GridProbes {
    fn hour(now: f64) -> usize {
        (now / 3600.0) as usize
    }
}

impl ProbeSource for GridProbes {
    fn avail_prob(&self, id: usize, now: f64, _mu: f64) -> f64 {
        ((id * 31 + Self::hour(now) * 17) % 5) as f64 * 0.25
    }

    fn expected_duration(&self, id: usize) -> f64 {
        10.0 + (id % 7) as f64
    }

    fn slot_sig(&self, now: f64, _mu: f64) -> SlotSig {
        SlotSig::Bins(vec![Self::hour(now) as u16])
    }
}

/// The tentpole equivalence: for every indexed selector, `select_from` over
/// the maintained pool must equal `select` over the materialized
/// ascending-id candidate list — same elements, same order, same RNG draws
/// — at every step of a churning, feedback-driven, time-advancing run.
#[test]
fn indexed_select_from_is_bit_compatible_with_select() {
    for name in ["random", "priority", "safa", "oort"] {
        prop_check(8, 0xB17C0 ^ name.len() as u64, |rng| {
            let n = rng.range(5, 60);
            let probes = GridProbes;
            let mut set = CandidateSet::new(n);
            let mut eligible = vec![false; n];
            let mut fast = by_name(name).unwrap();
            let mut slow = by_name(name).unwrap();
            let mut now = 0.0f64;
            let mu = 80.0;
            for step in 0..25 {
                now += rng.uniform(0.0, 2500.0);
                // eligibility churn, mirrored into the indexed selector
                for _ in 0..rng.range(0, 8) {
                    let id = rng.below(n);
                    if eligible[id] {
                        eligible[id] = false;
                        set.remove(id);
                        fast.on_ineligible(id);
                    } else {
                        eligible[id] = true;
                        set.insert(id);
                        fast.on_eligible(id);
                    }
                }
                let cands: Vec<Candidate> = (0..n)
                    .filter(|&id| eligible[id])
                    .map(|id| Candidate {
                        id,
                        avail_prob: probes.avail_prob(id, now, mu),
                        expected_duration: probes.expected_duration(id),
                    })
                    .collect();
                let target = rng.range(0, n + 2);
                let seed = rng.next_u64();
                let mut r1 = Rng::new(seed);
                let mut r2 = Rng::new(seed);
                let pool = SelectPool { set: &set, probes: &probes, mu };
                let a = fast
                    .select_from(&pool, step, now, target, &mut r1)
                    .expect("all built-in selectors are indexed");
                // engines skip select() entirely on an empty pool
                let b = if cands.is_empty() {
                    Vec::new()
                } else {
                    let mut ctx = SelectionCtx {
                        round: step,
                        now,
                        target,
                        candidates: &cands,
                        rng: &mut r2,
                    };
                    slow.select(&mut ctx)
                };
                prop_assert(a == b, format!("{name} step {step}: {a:?} != {b:?}"))?;
                prop_assert(
                    r1.next_u64() == r2.next_u64(),
                    format!("{name} step {step}: rng state diverged"),
                )?;
                // identical feedback on both sides (drives oort's dirty
                // re-scores, promotions, and — with a small window — pacer
                // re-keys of the utility tree)
                let completed: Vec<(usize, f64, f64)> = a
                    .iter()
                    .take(3)
                    .map(|&id| (id, rng.below(40) as f64, 10.0 + (id % 7) as f64))
                    .collect();
                let missed: Vec<usize> = a.iter().skip(3).take(2).copied().collect();
                let fb = relay::selection::RoundFeedback {
                    round: step,
                    completed: &completed,
                    missed: &missed,
                    round_duration: 60.0,
                };
                fast.feedback(&fb);
                slow.feedback(&fb);
            }
            Ok(())
        });
    }
}

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// End-to-end at scale: 20k-learner lazy DynAvail **async** cells run the
/// intelligent selectors through the indexed path — deterministic, all
/// merges delivered, accounting closed.
#[test]
fn larger_async_dynavail_cells_run_indexed_selectors() {
    for sel in ["oort", "priority"] {
        let cfg = ExpConfig {
            variant: "tiny".into(),
            total_learners: 20_000,
            rounds: 6,
            target_participants: 8,
            mode: RoundMode::Async { buffer_k: 4, max_staleness: Some(6) },
            avail: AvailMode::DynAvail,
            selector: sel.into(),
            mean_samples: 4,
            test_per_class: 2,
            eval_every: 1000,
            cooldown_rounds: 1,
            lr: 0.1,
            ..Default::default()
        };
        let a = run_experiment(cfg.clone(), exec()).unwrap();
        let b = run_experiment(cfg, exec()).unwrap();
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "{sel}: indexed async run not deterministic"
        );
        assert_eq!(a.rounds.len(), 6, "{sel}");
        let last = a.rounds.last().unwrap();
        let closed = last.cum_aggregated_secs.unwrap() + last.cum_waste_secs;
        assert!(
            (last.cum_resource_secs - closed).abs()
                <= 1e-6 * last.cum_resource_secs.max(1.0),
            "{sel}: accounting identity broken at 20k learners"
        );
    }
}

/// End-to-end at scale, against the materializing oracle: a 20k-learner
/// lazy DynAvail **sync** cell through the indexed engine must stay
/// byte-identical to the frozen reference's full-scan + materialized-select
/// loop — the strongest pin that indexing changed cost, not results.
#[test]
fn sync_20k_dynavail_matches_reference_byte_for_byte() {
    for sel in ["priority", "oort"] {
        let cfg = ExpConfig {
            variant: "tiny".into(),
            total_learners: 20_000,
            rounds: 3,
            target_participants: 5,
            mode: RoundMode::Deadline { deadline: 60.0 },
            avail: AvailMode::DynAvail,
            selector: sel.into(),
            mean_samples: 4,
            test_per_class: 2,
            eval_every: 2,
            cooldown_rounds: 1,
            lr: 0.1,
            ..Default::default()
        };
        let kernel = run_experiment(cfg.clone(), exec()).unwrap();
        let reference = run_reference_experiment(cfg, exec()).unwrap();
        assert_eq!(
            kernel.to_json().to_string(),
            reference.to_json().to_string(),
            "{sel}: indexed sync engine diverged from the frozen reference at 20k"
        );
    }
}
