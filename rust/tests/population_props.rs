//! Property tests for the population substrate: the incremental
//! `AvailabilityIndex` + `CandidateSet` must agree with a brute-force
//! full-population scan on randomized traces and event (advance) orders,
//! sampling must be byte-identical for 1 vs 8 shards, and the
//! end-to-end engines must be unchanged by the rewiring (the sync engines
//! are additionally pinned bytewise by `tests/kernel_equivalence.rs`).

use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::run_experiment;
use relay::population::{AvailabilityIndex, CandidateSet};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::sim::Availability;
use relay::trace::{LazyTraceSet, TraceConfig};
use relay::util::prop::{prop_assert, prop_check};
use relay::util::rng::Rng;

fn random_trace_config(rng: &mut Rng) -> TraceConfig {
    TraceConfig {
        median_session: rng.uniform(60.0, 1200.0),
        session_sigma: rng.uniform(0.4, 1.5),
        overnight_frac: rng.f64() * 0.3,
        peak_gap: rng.uniform(1800.0, 6.0 * 3600.0),
        diurnal_strength: rng.uniform(1.0, 8.0),
        phase_jitter: rng.uniform(600.0, 4.0 * 3600.0),
        nightly_block: if rng.bool(0.4) {
            Some((rng.uniform(3600.0, 6.0 * 3600.0), rng.uniform(60.0, 900.0)))
        } else {
            None
        },
    }
}

fn collect(idx: &AvailabilityIndex) -> Vec<usize> {
    let mut v = Vec::new();
    idx.for_each_available(|id| v.push(id));
    v
}

/// The core exactness property: after any sequence of time advances over
/// any generator configuration, the index's available set equals the
/// brute-force `available(id, t)` scan the engines used to run.
#[test]
fn availability_index_agrees_with_brute_force_scan() {
    prop_check(25, 0xA11A, |rng| {
        let config = random_trace_config(rng);
        let n = rng.range(1, 30);
        let seed = rng.next_u64();
        let shards = rng.range(1, 9);
        let mut idx = AvailabilityIndex::new(
            Availability::Lazy(LazyTraceSet::new(n, seed, config)),
            n,
            shards,
        );
        let oracle = Availability::Lazy(LazyTraceSet::new(n, seed, config));
        // randomized advance order: bursts of small steps and week-scale
        // jumps, so transition batches of every size are exercised
        let mut t = 0.0f64;
        for step in 0..30 {
            t += if rng.bool(0.3) {
                rng.uniform(50_000.0, 900_000.0) // multi-day / cross-week jump
            } else {
                rng.uniform(0.1, 2000.0)
            };
            idx.advance_to(t, 1);
            let got = collect(&idx);
            let want: Vec<usize> = (0..n).filter(|&id| oracle.available(id, t)).collect();
            prop_assert(
                got == want,
                format!(
                    "seed {seed} shards {shards} step {step} t={t}: \
                     index {got:?} != scan {want:?}"
                ),
            )?;
        }
        Ok(())
    });
}

/// Candidate-set rank sampling must be a pure function of (membership,
/// rng), independent of shard count, and bit-compatible with
/// `Rng::choose_k` over the ascending member list.
#[test]
fn candidate_set_sampling_shard_count_invariant() {
    prop_check(60, 0x5A3D, |rng| {
        let n = rng.range(1, 400);
        let members: Vec<usize> = (0..n).filter(|_| rng.bool(0.4)).collect();
        let k = rng.range(0, 20);
        let seed = rng.next_u64();
        let mut baseline: Option<Vec<usize>> = None;
        for shards in [1usize, 8, rng.range(2, 17)] {
            let mut set = CandidateSet::with_shards(n, shards);
            for &id in &members {
                set.insert(id);
            }
            prop_assert(set.len() == members.len(), "len mismatch")?;
            prop_assert(
                set.iter().collect::<Vec<_>>() == members,
                format!("{shards} shards: iteration order diverged"),
            )?;
            let sampled = set.sample_k(&mut Rng::new(seed), k);
            match &baseline {
                None => baseline = Some(sampled),
                Some(b) => prop_assert(
                    &sampled == b,
                    format!("{shards} shards: sample diverged from 1-shard baseline"),
                )?,
            }
        }
        // bit-compatibility with choose_k over the materialized list
        let want: Vec<usize> = Rng::new(seed)
            .choose_k(members.len(), k.min(members.len()))
            .into_iter()
            .map(|i| members[i])
            .collect();
        prop_assert(
            baseline.unwrap() == want,
            "sample_k diverged from choose_k over the member list",
        )
    });
}

/// Random insert/remove churn: rank queries stay consistent with a naive
/// sorted-vec model throughout.
#[test]
fn candidate_set_rank_queries_track_naive_model() {
    prop_check(40, 0xC0DE5, |rng| {
        let n = rng.range(1, 300);
        let mut set = CandidateSet::with_shards(n, rng.range(1, 9));
        let mut model = vec![false; n];
        for _ in 0..rng.range(1, 500) {
            let id = rng.below(n);
            if rng.bool(0.55) {
                set.insert(id);
                model[id] = true;
            } else {
                set.remove(id);
                model[id] = false;
            }
        }
        let members: Vec<usize> = (0..n).filter(|&i| model[i]).collect();
        prop_assert(set.len() == members.len(), "len diverged")?;
        for (rank, &id) in members.iter().enumerate() {
            prop_assert(
                set.nth(rank) == id,
                format!("nth({rank}) = {} != {id}", set.nth(rank)),
            )?;
            prop_assert(set.contains(id), "member not contained")?;
        }
        Ok(())
    });
}

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// End-to-end: the async engine rewired onto the population substrate is a
/// pure function of its config (sampling fast path included), across both
/// availability regimes and all selectors.
#[test]
fn async_runs_deterministic_on_population_substrate() {
    prop_check(6, 0xFA57, |rng| {
        let selectors = ["random", "priority", "oort", "safa"];
        let cfg = ExpConfig {
            variant: "tiny".into(),
            total_learners: rng.range(8, 40),
            rounds: rng.range(2, 6),
            target_participants: rng.range(2, 6),
            mode: RoundMode::Async {
                buffer_k: rng.range(1, 5),
                max_staleness: if rng.bool(0.5) { Some(rng.range(0, 4)) } else { None },
            },
            avail: if rng.bool(0.5) { AvailMode::AllAvail } else { AvailMode::DynAvail },
            selector: selectors[rng.below(4)].into(),
            mean_samples: 8,
            test_per_class: 2,
            eval_every: 2,
            cooldown_rounds: rng.range(0, 3),
            lr: 0.1,
            seed: rng.next_u64() % 10_000,
            ..Default::default()
        };
        let a = run_experiment(cfg.clone(), exec()).map_err(|e| format!("{e:#}"))?;
        let b = run_experiment(cfg.clone(), exec()).map_err(|e| format!("{e:#}"))?;
        prop_assert(
            a.to_json().to_string() == b.to_json().to_string(),
            format!("async run not deterministic for {:?}", cfg.selector),
        )?;
        prop_assert(a.rounds.len() == cfg.rounds, "missing merge records")
    });
}

/// A mid-scale lazy DynAvail async cell (the shape of the 100k/1M bench
/// cells) completes its merges through the incremental path — no
/// per-event full scans — and still closes its accounting.
#[test]
fn larger_async_dynavail_cell_completes() {
    let cfg = ExpConfig {
        variant: "tiny".into(),
        total_learners: 20_000,
        rounds: 10,
        target_participants: 8,
        mode: RoundMode::Async { buffer_k: 4, max_staleness: Some(6) },
        avail: AvailMode::DynAvail,
        selector: "random".into(),
        mean_samples: 4,
        test_per_class: 2,
        eval_every: 1000,
        cooldown_rounds: 1,
        lr: 0.1,
        ..Default::default()
    };
    let r = run_experiment(cfg, exec()).unwrap();
    assert_eq!(r.rounds.len(), 10);
    let last = r.rounds.last().unwrap();
    let agg = last.cum_aggregated_secs.unwrap();
    let closed = agg + last.cum_waste_secs;
    assert!(
        (last.cum_resource_secs - closed).abs() <= 1e-6 * last.cum_resource_secs.max(1.0),
        "accounting identity broken at 20k learners"
    );
}
