//! Distributional test coverage for `data::partition` (paper §5.1): the
//! three partition families must actually produce the statistical shapes
//! the paper's experiments assume — D1 near-uniform label marginals, D2
//! long-tail sample counts with near-IID label coverage, D3 hard
//! labels-per-learner limits with the configured within-learner skew —
//! deterministically per seed, with a stable parse/label round-trip.

use relay::data::partition::{
    label_coverage, LabelSkew, LearnerShard, Partitioner, PartitionScheme,
};
use relay::util::stats;

const CLASSES: usize = 20;
const LEARNERS: usize = 400;
const MEAN_SAMPLES: usize = 60;

fn assign(scheme: PartitionScheme, seed: u64) -> Vec<LearnerShard> {
    Partitioner::new(scheme, CLASSES, MEAN_SAMPLES).assign(LEARNERS, seed)
}

/// Aggregate per-label sample share across the whole population.
fn label_marginal(shards: &[LearnerShard]) -> Vec<f64> {
    let mut counts = vec![0usize; CLASSES];
    let mut total = 0usize;
    for s in shards {
        for &l in &s.labels {
            counts[l as usize] += 1;
            total += 1;
        }
    }
    counts.into_iter().map(|c| c as f64 / total.max(1) as f64).collect()
}

#[test]
fn iid_label_marginal_is_near_uniform() {
    let marginal = label_marginal(&assign(PartitionScheme::UniformIid, 11));
    let uniform = 1.0 / CLASSES as f64;
    for (label, share) in marginal.iter().enumerate() {
        assert!(
            (0.6 * uniform..=1.6 * uniform).contains(share),
            "label {label}: share {share} too far from uniform {uniform}"
        );
    }
}

#[test]
fn iid_sample_counts_are_tight_around_the_mean() {
    let shards = assign(PartitionScheme::UniformIid, 12);
    let counts: Vec<f64> = shards.iter().map(|s| s.len() as f64).collect();
    let mean = stats::mean(&counts);
    assert!(
        (mean - MEAN_SAMPLES as f64).abs() < 0.15 * MEAN_SAMPLES as f64,
        "mean count {mean} should track mean_samples {MEAN_SAMPLES}"
    );
    // the ±20% jitter bounds every shard
    for c in &counts {
        assert!(
            (0.75 * MEAN_SAMPLES as f64..=1.25 * MEAN_SAMPLES as f64).contains(c),
            "count {c} outside the jitter band"
        );
    }
}

#[test]
fn fedscale_counts_are_long_tailed_but_labels_near_iid() {
    let shards = assign(PartitionScheme::FedScale, 13);
    let counts: Vec<f64> = shards.iter().map(|s| s.len() as f64).collect();
    let p50 = stats::percentile(&counts, 50.0);
    let p90 = stats::percentile(&counts, 90.0);
    assert!(p90 > 2.0 * p50, "long tail expected: p50={p50} p90={p90}");
    // §E.1: most labels appear on >= 40% of learners
    let cov = label_coverage(&shards, CLASSES);
    let frac_covered = cov.iter().filter(|&&c| c >= 0.4).count() as f64 / CLASSES as f64;
    assert!(frac_covered > 0.8, "near-IID coverage expected, got {frac_covered}");
    // and no label disappears from the aggregate marginal
    for (label, share) in label_marginal(&shards).iter().enumerate() {
        assert!(*share > 0.01, "label {label} nearly absent: share {share}");
    }
}

#[test]
fn label_limited_respects_the_per_learner_label_budget() {
    for skew in [LabelSkew::Balanced, LabelSkew::Uniform, LabelSkew::Zipf] {
        let shards = assign(PartitionScheme::LabelLimited { labels: 3, skew }, 14);
        for (i, s) in shards.iter().enumerate() {
            let mut distinct: Vec<u16> = s.labels.clone();
            distinct.sort_unstable();
            distinct.dedup();
            assert!(
                distinct.len() <= 3,
                "learner {i}: {} distinct labels with a budget of 3 ({skew:?})",
                distinct.len()
            );
        }
    }
}

#[test]
fn label_limited_default_budget_tracks_num_classes() {
    // labels: 0 resolves to max(2, classes/10) inside the partitioner
    let want = (CLASSES / 10).max(2);
    let shards = assign(
        PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Balanced },
        15,
    );
    let mut saw_full_budget = false;
    for s in &shards {
        let mut distinct: Vec<u16> = s.labels.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(distinct.len() <= want);
        if distinct.len() == want {
            saw_full_budget = true;
        }
    }
    assert!(saw_full_budget, "no learner used the full default budget of {want}");
}

#[test]
fn label_limited_skews_shape_within_learner_distributions() {
    // L1 balanced: per-learner label counts differ by at most one
    let balanced =
        assign(PartitionScheme::LabelLimited { labels: 4, skew: LabelSkew::Balanced }, 16);
    for s in balanced.iter().take(50) {
        let mut counts = std::collections::HashMap::new();
        for &l in &s.labels {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        let max = *counts.values().max().unwrap();
        let min = *counts.values().min().unwrap();
        assert!(max - min <= 1, "balanced skew must be balanced: {max} vs {min}");
    }
    // L3 zipf(1.95): the top label dominates each learner's shard
    let zipf = assign(PartitionScheme::LabelLimited { labels: 4, skew: LabelSkew::Zipf }, 17);
    let mut top_share = 0.0;
    for s in &zipf {
        let mut counts = std::collections::HashMap::new();
        for &l in &s.labels {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        top_share += *counts.values().max().unwrap() as f64 / s.labels.len() as f64;
    }
    top_share /= zipf.len() as f64;
    assert!(top_share > 0.55, "zipf(1.95) top-label share only {top_share}");
    // and zipf is visibly more skewed than uniform
    let uniform = assign(PartitionScheme::LabelLimited { labels: 4, skew: LabelSkew::Uniform }, 17);
    let mut uniform_top = 0.0;
    for s in &uniform {
        let mut counts = std::collections::HashMap::new();
        for &l in &s.labels {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        uniform_top += *counts.values().max().unwrap() as f64 / s.labels.len() as f64;
    }
    uniform_top /= uniform.len() as f64;
    assert!(
        top_share > uniform_top + 0.1,
        "zipf ({top_share}) should dominate uniform ({uniform_top})"
    );
}

#[test]
fn assignment_is_deterministic_per_seed_and_varies_across_seeds() {
    for scheme in [
        PartitionScheme::UniformIid,
        PartitionScheme::FedScale,
        PartitionScheme::LabelLimited { labels: 3, skew: LabelSkew::Zipf },
    ] {
        let a = assign(scheme, 21);
        let b = assign(scheme, 21);
        assert_eq!(
            a.iter().map(|s| &s.labels).collect::<Vec<_>>(),
            b.iter().map(|s| &s.labels).collect::<Vec<_>>(),
            "{scheme:?}: same seed must reproduce byte-identically"
        );
        let c = assign(scheme, 22);
        assert_ne!(
            a.iter().map(|s| &s.labels).collect::<Vec<_>>(),
            c.iter().map(|s| &s.labels).collect::<Vec<_>>(),
            "{scheme:?}: different seeds must differ"
        );
    }
}

#[test]
fn parse_label_roundtrip_is_stable() {
    for name in ["iid", "fedscale", "label-balanced", "label-uniform", "label-zipf"] {
        let scheme = PartitionScheme::parse(name)
            .unwrap_or_else(|| panic!("'{name}' must parse"));
        assert_eq!(scheme.label(), name, "round-trip broke for '{name}'");
    }
    assert!(PartitionScheme::parse("bogus").is_none());
    assert!(PartitionScheme::parse("").is_none());
    // the label ignores the (non-serialized) labels count, by design
    let named = PartitionScheme::LabelLimited { labels: 7, skew: LabelSkew::Uniform };
    assert_eq!(named.label(), "label-uniform");
}
