//! Integration tests asserting the paper's *qualitative claims* hold in the
//! reproduction (small native-backend runs; the figure harness reproduces
//! them at scale). Each test names the paper section it checks.

use std::sync::Arc;

use relay::aggregation::scaling::ScalingRule;
use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::run_experiment;
use relay::data::partition::{LabelSkew, PartitionScheme};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

fn base() -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 40,
        rounds: 30,
        target_participants: 6,
        mean_samples: 20,
        test_per_class: 10,
        eval_every: 5,
        lr: 0.1,
        seed: 3,
        // tiny-variant tasks are sub-second; disable the selection-window
        // floor so timing-sensitive claims are visible at this scale
        min_round_duration: 0.0,
        ..Default::default()
    }
}

/// §3.2 / Fig. 2: SAFA wastes a large fraction of resources; the oracle
/// variant reaches the same accuracy with much less.
#[test]
fn safa_wastes_oracle_saves() {
    let mut safa = base();
    safa.selector = "safa".into();
    safa.use_saa = true;
    safa.staleness_threshold = Some(2);
    safa.scaling = ScalingRule::Equal;
    safa.mode = RoundMode::Deadline { deadline: 3.0 };
    safa.avail = AvailMode::AllAvail;
    let plain = run_experiment(safa.clone(), exec()).unwrap();
    safa.oracle = true;
    let oracle = run_experiment(safa, exec()).unwrap();

    assert!(plain.waste_fraction() > 0.10, "SAFA should waste: {}", plain.waste_fraction());
    assert!(
        oracle.final_resource_hours() < plain.final_resource_hours() * 0.95,
        "oracle {}h vs plain {}h",
        oracle.final_resource_hours(),
        plain.final_resource_hours()
    );
    assert_eq!(plain.final_accuracy(), oracle.final_accuracy());
}

/// §4.2 / Fig. 9: enabling SAA (stale aggregation) must not hurt accuracy
/// and must reduce waste under a tight deadline.
#[test]
fn saa_reduces_waste_at_same_or_better_quality() {
    let mk = |saa: bool| {
        let mut c = base();
        c.use_saa = saa;
        c.scaling = ScalingRule::Relay { beta: 0.35 };
        c.mode = RoundMode::Deadline { deadline: 2.0 };
        c.avail = AvailMode::AllAvail;
        c.rounds = 40;
        run_experiment(c, exec()).unwrap()
    };
    let with = mk(true);
    let without = mk(false);
    assert!(
        with.waste_fraction() < without.waste_fraction(),
        "SAA waste {} !< no-SAA waste {}",
        with.waste_fraction(),
        without.waste_fraction()
    );
    let (a, b) = (with.final_accuracy().unwrap(), without.final_accuracy().unwrap());
    assert!(a >= b - 0.08, "SAA materially hurt accuracy: {a} vs {b}");
}

/// §4.1 / Fig. 6: under dynamic availability + non-IID data, least-available
/// prioritization reaches more unique learners than Oort.
#[test]
fn priority_reaches_more_unique_learners_than_oort() {
    let mk = |sel: &str| {
        let mut c = base();
        c.selector = sel.into();
        c.avail = AvailMode::DynAvail;
        c.partition = PartitionScheme::LabelLimited { labels: 2, skew: LabelSkew::Uniform };
        c.total_learners = 60;
        c.rounds = 40;
        run_experiment(c, exec()).unwrap()
    };
    let pri = mk("priority");
    let oort = mk("oort");
    let u_pri = pri.rounds.last().unwrap().unique_participants;
    let u_oort = oort.rounds.last().unwrap().unique_participants;
    assert!(
        u_pri + 3 >= u_oort,
        "priority should cover at least as many learners: {u_pri} vs {u_oort}"
    );
}

/// §4.1 APT: with stragglers in flight the target shrinks, so RELAY+APT
/// selects fewer fresh participants and uses fewer resources.
#[test]
fn apt_saves_resources() {
    let mk = |apt: bool| {
        let mut c = base().relay();
        c.apt = apt;
        c.mode = RoundMode::Deadline { deadline: 2.0 };
        c.avail = AvailMode::AllAvail;
        c.target_participants = 8;
        c.rounds = 40;
        run_experiment(c, exec()).unwrap()
    };
    let with = mk(true);
    let without = mk(false);
    assert!(
        with.final_resource_hours() <= without.final_resource_hours() * 1.05,
        "APT should not increase resources: {} vs {}",
        with.final_resource_hours(),
        without.final_resource_hours()
    );
}

/// §4.2.4 / Fig. 10: the four scaling rules produce different trajectories
/// (the weights actually differ) and all still learn.
#[test]
fn scaling_rules_differ_but_all_learn() {
    let mut accs = Vec::new();
    for rule in ["equal", "dynsgd", "adasgd", "relay"] {
        let mut c = base().relay();
        c.apt = false;
        c.scaling = ScalingRule::parse(rule).unwrap();
        c.mode = RoundMode::Deadline { deadline: 2.0 };
        c.avail = AvailMode::AllAvail;
        c.rounds = 40;
        let r = run_experiment(c, exec()).unwrap();
        accs.push((rule, r.final_accuracy().unwrap()));
    }
    for (rule, acc) in &accs {
        assert!(*acc > 0.4, "{rule} failed to learn: {acc}");
    }
}

/// The logical endpoint of SAA (FedBuff-style buffered async): with a
/// staleness bound, the async regime reaches accuracy at least matching the
/// DL regime at equal resource-hours on the tiny benchmark — and wastes
/// less, because the buffer keeps the stragglers a tight deadline discards.
#[test]
fn async_matches_deadline_at_equal_resources() {
    let mk = |mode: RoundMode| {
        let mut c = base();
        c.mode = mode;
        c.avail = AvailMode::AllAvail;
        c.rounds = 40;
        c.cooldown_rounds = 2;
        c.eval_every = 2;
        run_experiment(c, exec()).unwrap()
    };
    let dl = mk(RoundMode::Deadline { deadline: 2.0 });
    let asy = mk(RoundMode::Async { buffer_k: 6, max_staleness: Some(8) });

    // equal-resource comparison: best accuracy either regime reached within
    // the smaller of the two total device-hour budgets
    let budget = dl.final_resource_hours().min(asy.final_resource_hours());
    let acc_within = |r: &relay::metrics::ExperimentResult| {
        r.rounds
            .iter()
            .filter(|rec| rec.cum_resource_secs / 3600.0 <= budget + 1e-9)
            .filter_map(|rec| rec.test_accuracy)
            .fold(f64::NEG_INFINITY, f64::max)
    };
    let (a_async, a_dl) = (acc_within(&asy), acc_within(&dl));
    assert!(
        a_async.is_finite() && a_dl.is_finite(),
        "both regimes must eval within the shared budget: async {a_async}, dl {a_dl}"
    );
    assert!(a_async > 0.4, "async regime failed to learn: {a_async}");
    assert!(
        a_async >= a_dl - 0.05,
        "async accuracy {a_async} fell below DL {a_dl} at equal resource-hours ({budget}h)"
    );
    // the waste mechanism is the point: the tight deadline throws away
    // every straggler (no SAA here), the buffer merges them
    assert!(
        asy.waste_fraction() < dl.waste_fraction(),
        "async waste {} !< DL waste {}",
        asy.waste_fraction(),
        dl.waste_fraction()
    );
}

/// Fig. 12: HS4 (all devices 2x faster) shortens wall-clock time to finish
/// the same number of rounds in OC mode.
#[test]
fn faster_hardware_shortens_rounds() {
    let mk = |hs| {
        let mut c = base();
        c.hardware = hs;
        c.avail = AvailMode::AllAvail;
        run_experiment(c, exec()).unwrap()
    };
    let hs1 = mk(relay::learners::HardwareScenario::Hs1);
    let hs4 = mk(relay::learners::HardwareScenario::Hs4);
    assert!(
        hs4.final_sim_time() < hs1.final_sim_time(),
        "HS4 {} !< HS1 {}",
        hs4.final_sim_time(),
        hs1.final_sim_time()
    );
}

/// Table 2 directionality: IID semi-centralized beats heavily skewed zipf.
#[test]
fn centralized_iid_beats_zipf() {
    use relay::coordinator::centralized::run_centralized;
    let mk = |p: PartitionScheme| {
        let mut c = base();
        c.partition = p;
        c.mean_samples = 40;
        run_centralized(&c, exec(), 25).unwrap().final_accuracy
    };
    let iid = mk(PartitionScheme::UniformIid);
    let zipf = mk(PartitionScheme::LabelLimited { labels: 2, skew: LabelSkew::Zipf });
    assert!(iid >= zipf - 0.05, "iid {iid} vs zipf {zipf}");
}
