//! Randomized round-trip and robustness properties of the run-log codec.
//!
//! * encode → decode over random event streams is the identity (including
//!   across segment-rotation boundaries);
//! * a truncated or bit-flipped log never panics the decoder: it yields an
//!   exact prefix of the original stream, flagged not-clean when the damage
//!   is inside a frame.

use relay::runlog::tail::SegmentCursor;
use relay::runlog::{
    decode_segments, DirSink, DirTailer, LogSink, MemSink, RunEvent, RunLogger, SEGMENT_EVENTS,
};
use relay::util::rng::Rng;

fn random_event(rng: &mut Rng) -> RunEvent {
    let f = |rng: &mut Rng| rng.uniform(-1e6, 1e6);
    let u = |rng: &mut Rng| rng.below(1 << 20) as u64;
    match rng.below(20) {
        0 => RunEvent::RunStart {
            label: format!("run-{}", rng.below(1000)),
            perplexity: rng.bool(0.5),
            mode: rng.below(3) as u8,
            buffer_k: u(rng),
            max_staleness: if rng.bool(0.5) { Some(u(rng)) } else { None },
            rounds: u(rng),
            eval_every: 1 + u(rng),
            use_saa: rng.bool(0.5),
            staleness_threshold: if rng.bool(0.5) { Some(u(rng)) } else { None },
        },
        1 => RunEvent::RoundStart { round: u(rng), now: f(rng) },
        2 => RunEvent::Eligibility { count: u(rng) },
        3 => RunEvent::Selected { learner: u(rng) },
        4 => RunEvent::FaultDecision {
            kind: rng.below(5) as u8,
            learner: u(rng),
            round: u(rng),
        },
        5 => RunEvent::TaskDropout { learner: u(rng), spent: f(rng) },
        6 => RunEvent::StragglerSpend {
            learner: u(rng),
            duration: f(rng),
            fate: rng.below(3) as u8,
        },
        7 => RunEvent::FreshSpend {
            learner: u(rng),
            duration: f(rng),
            corrupt: rng.bool(0.5),
        },
        8 => RunEvent::Trained {
            learner: u(rng),
            mean_loss: f(rng),
            duration: f(rng),
            fresh: rng.bool(0.5),
        },
        9 => RunEvent::StaleDelivery {
            learner: u(rng),
            origin_round: u(rng),
            duration: f(rng),
        },
        10 => RunEvent::EvalDone { loss: f(rng), acc: rng.f64() },
        11 => RunEvent::RoundEnd { round_duration: f(rng) },
        12 => RunEvent::KernelPop { at: f(rng), class: rng.below(5) as u8 },
        13 => RunEvent::AsyncSpawn {
            learner: u(rng),
            duration: f(rng),
            dropped_after: if rng.bool(0.5) { Some(f(rng)) } else { None },
        },
        14 => RunEvent::AsyncDropout { learner: u(rng), spent: f(rng) },
        15 => RunEvent::AsyncDelivery {
            learner: u(rng),
            origin_version: u(rng),
            duration: f(rng),
            mean_loss: f(rng),
            corrupt: rng.bool(0.5),
        },
        16 => RunEvent::MergeCommit {
            eval: if rng.bool(0.5) { Some((f(rng), rng.f64())) } else { None },
        },
        17 => RunEvent::AsyncBurn { end: f(rng) },
        18 => RunEvent::SweepLeftover { secs: f(rng) },
        _ => RunEvent::RunEnd,
    }
}

/// Log `events` through the real logger/sink pair; returns the segments.
fn log_to_segments(events: &[RunEvent]) -> Vec<Vec<u8>> {
    let sink = MemSink::default();
    let mut logger = RunLogger::new(Box::new(sink.clone()));
    for ev in events {
        logger.emit(|| ev.clone());
    }
    logger.finish().expect("memory sink never fails");
    sink.segments()
}

fn is_prefix(decoded: &[RunEvent], original: &[RunEvent]) -> bool {
    decoded.len() <= original.len()
        && decoded.iter().zip(original.iter()).all(|(a, b)| a == b)
}

#[test]
fn random_streams_round_trip_bit_exactly() {
    let mut rng = Rng::new(0xC0DEC);
    for trial in 0..20 {
        let n = rng.range(1, 400);
        let events: Vec<RunEvent> = (0..n).map(|_| random_event(&mut rng)).collect();
        let segments = log_to_segments(&events);
        let (decoded, stats) = decode_segments(&segments);
        assert!(stats.clean, "trial {trial}: clean stream flagged: {:?}", stats.note);
        assert_eq!(stats.frames, n, "trial {trial}: frame count");
        assert_eq!(decoded, events, "trial {trial}: round trip not identity");
    }
}

#[test]
fn rotation_boundary_round_trips_across_segments() {
    let mut rng = Rng::new(0x5E6);
    // enough events to force at least one rotation, landing just past the
    // boundary so the second segment is small
    let n = SEGMENT_EVENTS as usize + 17;
    let events: Vec<RunEvent> = (0..n).map(|_| random_event(&mut rng)).collect();
    let segments = log_to_segments(&events);
    assert_eq!(segments.len(), 2, "one rotation expected at {SEGMENT_EVENTS} events");
    let (decoded, stats) = decode_segments(&segments);
    assert!(stats.clean, "rotated stream flagged: {:?}", stats.note);
    assert_eq!(stats.segments, 2);
    assert_eq!(decoded, events);
}

#[test]
fn truncated_logs_decode_to_a_clean_prefix_without_panicking() {
    let mut rng = Rng::new(0x7121C);
    let events: Vec<RunEvent> = (0..200).map(|_| random_event(&mut rng)).collect();
    let full = log_to_segments(&events);
    assert_eq!(full.len(), 1);
    for _ in 0..100 {
        let cut = rng.below(full[0].len());
        let segments = vec![full[0][..cut].to_vec()];
        let (decoded, _stats) = decode_segments(&segments);
        assert!(
            is_prefix(&decoded, &events),
            "truncation at byte {cut} produced a non-prefix ({} events)",
            decoded.len()
        );
    }
    // cutting at the very start kills even the magic header
    let (decoded, stats) = decode_segments(&[Vec::new()]);
    assert!(decoded.is_empty());
    assert!(!stats.clean);
}

#[test]
fn bit_flips_are_detected_and_yield_a_prefix() {
    let mut rng = Rng::new(0xF11B);
    let events: Vec<RunEvent> = (0..200).map(|_| random_event(&mut rng)).collect();
    let full = log_to_segments(&events);
    for _ in 0..100 {
        let mut seg = full[0].clone();
        let byte = rng.below(seg.len());
        seg[byte] ^= 1 << rng.below(8);
        let (decoded, stats) = decode_segments(&[seg]);
        assert!(
            !stats.clean,
            "single-bit flip at byte {byte} went undetected ({} events)",
            decoded.len()
        );
        assert!(
            is_prefix(&decoded, &events),
            "flip at byte {byte} produced a non-prefix"
        );
    }
}

/// Tailing contract under torn tails: feeding a segment to the cursor in
/// arbitrary increments (the on-disk states a concurrent writer leaves
/// behind) yields each event exactly once, never flags a merely-torn tail
/// as corrupt, and converges to the full stream.
#[test]
fn tailing_random_increments_yields_each_event_exactly_once() {
    let mut rng = Rng::new(0x7A11);
    for trial in 0..20 {
        let n = rng.range(1, 200);
        let events: Vec<RunEvent> = (0..n).map(|_| random_event(&mut rng)).collect();
        let seg = log_to_segments(&events).remove(0);
        let mut cursor = SegmentCursor::new();
        let mut out = Vec::new();
        let mut len = 0usize;
        while len < seg.len() {
            len = (len + 1 + rng.below(64)).min(seg.len());
            cursor.drain(&seg[..len], &mut out);
            assert!(
                cursor.corrupt().is_none(),
                "trial {trial}: torn tail misread as corrupt at byte {len}: {:?}",
                cursor.corrupt()
            );
            assert!(is_prefix(&out, &events), "trial {trial}: non-prefix at byte {len}");
        }
        assert_eq!(out, events, "trial {trial}: incremental decode not exactly-once");
    }
}

/// A bit-flipped segment tail sticks as corrupt (or torn) without panics or
/// duplicates; once the writer rotates, the tailer records the skip exactly
/// once and resumes cleanly at the next segment boundary.
#[test]
fn dir_tailer_survives_random_tail_damage_across_rotation() {
    let mut rng = Rng::new(0xDA4A6E);
    for trial in 0..10 {
        let n1 = rng.range(2, 120);
        let n2 = rng.range(1, 120);
        let first: Vec<RunEvent> = (0..n1).map(|_| random_event(&mut rng)).collect();
        let second: Vec<RunEvent> = (0..n2).map(|_| random_event(&mut rng)).collect();
        let mut damaged = log_to_segments(&first).remove(0);
        let byte = 8 + rng.below(damaged.len() - 8); // past the magic
        damaged[byte] ^= 1 << rng.below(8);
        let dir = std::env::temp_dir().join(format!(
            "relay-props-tail-{}-{trial}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("mkdir");
        std::fs::write(dir.join("seg-00000.rlog"), &damaged).expect("write seg-0");
        let mut tailer = DirTailer::open(&dir);
        let got = tailer.poll().expect("poll damaged segment");
        assert!(
            is_prefix(&got, &first) && got.len() < first.len(),
            "trial {trial}: flip at byte {byte} must cut the stream to a strict prefix"
        );
        // damage is sticky until rotation: re-polling adds nothing
        assert!(tailer.poll().expect("re-poll").is_empty(), "trial {trial}: duplicate events");
        std::fs::write(dir.join("seg-00001.rlog"), log_to_segments(&second).remove(0))
            .expect("write seg-1");
        let resumed = tailer.poll().expect("poll after rotation");
        assert_eq!(resumed, second, "trial {trial}: must resume at the new segment boundary");
        assert_eq!(tailer.stats().segments_finalized, 1, "trial {trial}");
        assert_eq!(
            tailer.stats().skipped.len(),
            1,
            "trial {trial}: the damaged tail is skipped exactly once: {:?}",
            tailer.stats().skipped
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// End-to-end live-follow: a tailer polling *while* a real `DirSink` writer
/// appends (buffered, so polls routinely land mid-frame) sees every event
/// exactly once, across a rotation, with nothing skipped.
#[test]
fn live_tailer_follows_a_writing_dir_sink_exactly_once() {
    let mut rng = Rng::new(0x11FE);
    let dir = std::env::temp_dir().join(format!("relay-props-live-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let n = SEGMENT_EVENTS as usize + 50;
    let events: Vec<RunEvent> = (0..n).map(|_| random_event(&mut rng)).collect();
    let sink = DirSink::create(&dir).expect("create dir sink");
    let mut logger = RunLogger::new(Box::new(sink));
    let mut tailer = DirTailer::open(&dir);
    let mut seen = Vec::new();
    for ev in &events {
        logger.emit(|| ev.clone());
        if rng.bool(0.01) {
            seen.extend(tailer.poll().expect("mid-write poll"));
            assert!(is_prefix(&seen, &events), "mid-write non-prefix at {}", seen.len());
        }
    }
    logger.finish().expect("finish log");
    seen.extend(tailer.poll().expect("final poll"));
    assert_eq!(seen, events, "every frame exactly once, no duplicates");
    assert!(
        tailer.stats().skipped.is_empty(),
        "clean log must skip nothing: {:?}",
        tailer.stats().skipped
    );
    assert_eq!(tailer.stats().segments_finalized, 1, "one rotation crossed");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The logger's error-poisoning contract: the first sink failure mutes all
/// later emits and surfaces exactly once, from `finish`.
#[test]
fn sink_errors_poison_the_logger_and_surface_from_finish() {
    struct FailingSink {
        writes_before_failure: usize,
    }
    impl LogSink for FailingSink {
        fn write(&mut self, _frame: &[u8]) -> std::io::Result<()> {
            if self.writes_before_failure == 0 {
                return Err(std::io::Error::other("disk full"));
            }
            self.writes_before_failure -= 1;
            Ok(())
        }
        fn rotate(&mut self) -> std::io::Result<()> {
            Ok(())
        }
        fn finish(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
    let mut logger = RunLogger::new(Box::new(FailingSink { writes_before_failure: 2 }));
    for _ in 0..10 {
        logger.emit(|| RunEvent::RunEnd);
    }
    assert_eq!(logger.events(), 2, "only pre-failure writes count");
    assert!(!logger.enabled(), "first failure must poison the logger");
    let err = logger.finish().expect_err("the deferred error must surface");
    assert!(err.to_string().contains("disk full"), "unexpected error: {err:#}");
}
