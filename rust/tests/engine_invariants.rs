//! Property-style integration tests over the coordinator: randomized
//! configurations must preserve the accounting and protocol invariants
//! regardless of selector/mode/availability combination. Uses the in-house
//! property runner (`relay::util::prop`) since proptest is unavailable
//! offline (DESIGN.md §2).

use std::sync::Arc;

use relay::aggregation::scaling::ScalingRule;
use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::run_experiment;
use relay::data::partition::{LabelSkew, PartitionScheme};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::util::prop::{prop_assert, prop_check, PropResult};
use relay::util::rng::Rng;

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// Draw a random-but-valid experiment configuration.
fn random_cfg(rng: &mut Rng) -> ExpConfig {
    let selectors = ["random", "oort", "priority", "safa"];
    let partitions = [
        PartitionScheme::UniformIid,
        PartitionScheme::FedScale,
        PartitionScheme::LabelLimited { labels: 2, skew: LabelSkew::Uniform },
        PartitionScheme::LabelLimited { labels: 2, skew: LabelSkew::Zipf },
        PartitionScheme::LabelLimited { labels: 2, skew: LabelSkew::Balanced },
    ];
    let mut c = ExpConfig {
        variant: "tiny".into(),
        total_learners: rng.range(5, 40),
        rounds: rng.range(3, 10),
        target_participants: rng.range(1, 8),
        mean_samples: rng.range(6, 30),
        test_per_class: 4,
        eval_every: rng.range(1, 5),
        lr: 0.05,
        selector: selectors[rng.below(selectors.len())].into(),
        partition: partitions[rng.below(partitions.len())],
        use_saa: rng.bool(0.5),
        staleness_threshold: if rng.bool(0.5) { Some(rng.range(0, 6)) } else { None },
        apt: rng.bool(0.3),
        oracle: rng.bool(0.2),
        scaling: [
            ScalingRule::Equal,
            ScalingRule::DynSgd,
            ScalingRule::AdaSgd,
            ScalingRule::Relay { beta: 0.35 },
        ][rng.below(4)],
        avail: if rng.bool(0.5) { AvailMode::AllAvail } else { AvailMode::DynAvail },
        mode: if rng.bool(0.5) {
            RoundMode::OverCommit { factor: 1.0 + rng.f64() * 0.5 }
        } else {
            RoundMode::Deadline { deadline: 10.0 + rng.f64() * 200.0 }
        },
        seed: rng.next_u64() % 10_000,
        ..Default::default()
    };
    // oracle only meaningful with SAA + threshold
    if c.oracle {
        c.use_saa = true;
        c.staleness_threshold = Some(c.staleness_threshold.unwrap_or(3));
    }
    c
}

fn check_invariants(cfg: &ExpConfig) -> PropResult {
    let r = run_experiment(cfg.clone(), exec()).map_err(|e| format!("run failed: {e:#}"))?;
    prop_assert(r.rounds.len() == cfg.rounds, "missing round records")?;

    let mut prev_time = 0.0;
    let mut prev_res = 0.0;
    let mut prev_waste = 0.0;
    for rec in &r.rounds {
        prop_assert(
            rec.sim_time >= prev_time,
            format!("time went backwards at round {}", rec.round),
        )?;
        prop_assert(
            rec.cum_resource_secs >= prev_res - 1e-9,
            format!("resources decreased at round {}", rec.round),
        )?;
        prop_assert(
            rec.cum_waste_secs >= prev_waste - 1e-9,
            format!("waste decreased at round {}", rec.round),
        )?;
        prop_assert(
            rec.cum_waste_secs <= rec.cum_resource_secs + 1e-6,
            format!(
                "waste {} exceeds resources {} at round {}",
                rec.cum_waste_secs, rec.cum_resource_secs, rec.round
            ),
        )?;
        prop_assert(
            rec.round_duration >= 0.0,
            format!("negative duration at round {}", rec.round),
        )?;
        if let RoundMode::Deadline { deadline } = cfg.mode {
            prop_assert(
                rec.round_duration <= deadline + 1e-6,
                format!("round {} exceeded deadline", rec.round),
            )?;
        }
        prop_assert(
            rec.unique_participants <= cfg.total_learners,
            "unique participants exceed population",
        )?;
        // Fresh updates come only from this round's cohort: every fresh
        // update is a selected participant that finished before round end.
        prop_assert(
            rec.fresh_updates <= rec.selected,
            format!(
                "round {}: fresh updates {} exceed the selected cohort {}",
                rec.round, rec.fresh_updates, rec.selected
            ),
        )?;
        if let Some(acc) = rec.test_accuracy {
            prop_assert((0.0..=1.0).contains(&acc), format!("accuracy {acc} out of range"))?;
        }
        prev_time = rec.sim_time;
        prev_res = rec.cum_resource_secs;
        prev_waste = rec.cum_waste_secs;
    }
    Ok(())
}

#[test]
fn accounting_invariants_hold_for_random_configs() {
    prop_check(40, 0xEEF1, |rng| {
        let cfg = random_cfg(rng);
        check_invariants(&cfg)
    });
}

#[test]
fn runs_are_deterministic_per_seed() {
    prop_check(8, 0xDE7E, |rng| {
        let cfg = random_cfg(rng);
        let a = run_experiment(cfg.clone(), exec()).map_err(|e| e.to_string())?;
        let b = run_experiment(cfg.clone(), exec()).map_err(|e| e.to_string())?;
        prop_assert(
            a.final_accuracy() == b.final_accuracy()
                && a.rounds.last().map(|r| r.cum_resource_secs)
                    == b.rounds.last().map(|r| r.cum_resource_secs),
            "same seed produced different results",
        )
    });
}

#[test]
fn oracle_never_uses_more_resources() {
    prop_check(10, 0x0AC1E, |rng| {
        let mut cfg = random_cfg(rng);
        cfg.selector = "safa".into();
        cfg.use_saa = true;
        cfg.staleness_threshold = Some(rng.range(0, 4));
        cfg.mode = RoundMode::Deadline { deadline: 20.0 + rng.f64() * 60.0 };
        cfg.oracle = false;
        let plain = run_experiment(cfg.clone(), exec()).map_err(|e| e.to_string())?;
        cfg.oracle = true;
        let oracle = run_experiment(cfg, exec()).map_err(|e| e.to_string())?;
        prop_assert(
            oracle.final_resource_hours() <= plain.final_resource_hours() + 1e-9,
            format!(
                "oracle used more: {} vs {}",
                oracle.final_resource_hours(),
                plain.final_resource_hours()
            ),
        )
    });
}

#[test]
fn oracle_reaches_same_accuracy() {
    // the oracle only skips never-aggregated work, so the model trajectory
    // (and final accuracy) must be identical to plain SAFA
    let mut rng = Rng::new(77);
    for _ in 0..5 {
        let mut cfg = random_cfg(&mut rng);
        cfg.selector = "safa".into();
        cfg.use_saa = true;
        cfg.staleness_threshold = Some(2);
        cfg.mode = RoundMode::Deadline { deadline: 50.0 };
        cfg.oracle = false;
        let plain = run_experiment(cfg.clone(), exec()).unwrap();
        cfg.oracle = true;
        let oracle = run_experiment(cfg, exec()).unwrap();
        assert_eq!(
            plain.final_accuracy(),
            oracle.final_accuracy(),
            "oracle must not change the model trajectory"
        );
    }
}

#[test]
fn cooldown_caps_participation_rate() {
    // with cooldown 5 and 12 learners, a learner can participate at most
    // every 6th round; total fresh updates over R rounds <= R * pop / 6 + slack
    let cfg = ExpConfig {
        variant: "tiny".into(),
        total_learners: 12,
        rounds: 18,
        target_participants: 12,
        cooldown_rounds: 5,
        avail: AvailMode::AllAvail,
        mean_samples: 8,
        test_per_class: 2,
        eval_every: 100,
        ..Default::default()
    };
    let r = run_experiment(cfg, exec()).unwrap();
    let total_fresh: usize = r.rounds.iter().map(|x| x.fresh_updates).sum();
    assert!(total_fresh <= 12 * 3 + 12, "cooldown not enforced: {total_fresh}");
}

#[test]
fn unbounded_staleness_never_discards() {
    let mut rng = Rng::new(5);
    for _ in 0..5 {
        let mut cfg = random_cfg(&mut rng);
        cfg.use_saa = true;
        cfg.staleness_threshold = None;
        cfg.oracle = false;
        cfg.avail = AvailMode::AllAvail; // no dropouts
        let r = run_experiment(cfg, exec()).unwrap();
        let discarded: usize = r.rounds.iter().map(|x| x.discarded).sum();
        assert_eq!(discarded, 0, "unbounded staleness must never discard");
    }
}
