//! Golden `ExperimentResult` baselines for a small selector × round-mode
//! cell matrix, pinning post-PR4 selection trajectories against silent
//! drift (PR 4 deliberately re-normalized IPS tie-breaking with no goldens
//! committed to witness it; this suite closes that gap).
//!
//! Workflow:
//!
//! * a committed golden under `tests/golden/` is compared byte-for-byte;
//! * a *missing* golden is bootstrapped (written and reported) on first
//!   run, so a fresh checkout self-pins from its first `cargo test` — the
//!   written files are meant to be committed;
//! * `RELAY_WRITE_GOLDEN=1 cargo test --test golden_baselines` force-
//!   rewrites after an intentional behavioral change (review the diff!).

use std::path::PathBuf;
use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::{run_experiment, run_experiment_logged};
use relay::runlog::{decode_segments, replay, MemSink};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Straggler-rich DynAvail base so the trajectories exercise selection,
/// staleness, and churn — the paths most likely to drift silently.
fn cell_cfg(selector: &str, mode: RoundMode) -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 14,
        rounds: 5,
        target_participants: 4,
        mode,
        avail: AvailMode::DynAvail,
        selector: selector.into(),
        use_saa: true,
        staleness_threshold: Some(3),
        mean_samples: 8,
        test_per_class: 4,
        eval_every: 2,
        cooldown_rounds: 1,
        min_round_duration: 0.0,
        lr: 0.1,
        ..Default::default()
    }
}

#[test]
fn selector_mode_matrix_matches_goldens() {
    let force_write = std::env::var("RELAY_WRITE_GOLDEN").is_ok();
    let modes = [
        ("oc", RoundMode::OverCommit { factor: 1.3 }),
        ("dl", RoundMode::Deadline { deadline: 2.0 }),
        ("async", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }),
    ];
    for selector in ["random", "oort", "priority", "safa"] {
        for (mode_name, mode) in modes.iter() {
            let label = format!("traj-{selector}-{mode_name}");
            let mut cfg = cell_cfg(selector, *mode);
            cfg.label = label.clone();
            let result = run_experiment(cfg.clone(), exec())
                .unwrap_or_else(|e| panic!("cell '{label}' failed: {e:#}"));
            let bytes = result.to_json().to_string();
            // replay oracle: a logged run of the same cell must leave the
            // result bytes untouched, decode cleanly, and re-derive the
            // identical JSON from the event stream alone
            let sink = MemSink::default();
            let logged = run_experiment_logged(cfg, exec(), Box::new(sink.clone()))
                .unwrap_or_else(|e| panic!("cell '{label}' logged run failed: {e:#}"));
            assert_eq!(
                logged.to_json().to_string(),
                bytes,
                "cell '{label}': enabling the run log perturbed the result"
            );
            let (events, stats) = decode_segments(&sink.segments());
            assert!(
                stats.clean,
                "cell '{label}': run log did not decode cleanly: {:?}",
                stats.note
            );
            let replayed = replay(&events)
                .unwrap_or_else(|e| panic!("cell '{label}' replay failed: {e:#}"));
            assert_eq!(
                replayed.to_json().to_string(),
                bytes,
                "cell '{label}': replay oracle diverged from the engine"
            );
            let path = golden_dir().join(format!("{label}.json"));
            if force_write || !path.exists() {
                std::fs::create_dir_all(golden_dir()).unwrap();
                match std::fs::write(&path, &bytes) {
                    Ok(()) => {
                        if !force_write {
                            eprintln!(
                                "[golden] bootstrapped {} — commit it to pin this trajectory",
                                path.display()
                            );
                        }
                    }
                    Err(e) => eprintln!("[golden] cannot write {}: {e}", path.display()),
                }
            } else {
                let golden = std::fs::read_to_string(&path).unwrap();
                assert_eq!(
                    golden, bytes,
                    "cell '{label}': trajectory drifted from the committed golden {path:?} \
                     (if intentional, regenerate with RELAY_WRITE_GOLDEN=1)"
                );
            }
        }
    }
}

/// The golden bytes must themselves be valid, finite JSON — a golden that
/// pins a serialization bug would pin the bug.
#[test]
fn golden_cells_serialize_to_valid_json() {
    let cfg = cell_cfg("priority", RoundMode::Deadline { deadline: 2.0 });
    let r = run_experiment(cfg, exec()).unwrap();
    let s = r.to_json().to_string();
    relay::util::json::Json::parse(&s).expect("golden cell output must parse");
    assert!(!s.contains("NaN"), "non-finite value leaked: {s}");
}
