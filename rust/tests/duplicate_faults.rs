//! Audit of `Accounting` under the `duplicate` fault.
//!
//! A duplicate delivery is deduped by the server: it increments the fault
//! counter and nothing else — no resource is spent twice, no update is
//! aggregated twice. Fault decisions are stateless (seed-derived per
//! (kind, learner, round)), so toggling the duplicate rate must leave every
//! other field of the trajectory bitwise unchanged. These tests pin both
//! properties plus the terminal-bucket accounting identity
//! `spent == aggregated + wasted` (in-flight is swept to waste at run end)
//! under duplicate-heavy configs in all three engines.

use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::{run_experiment_logged, Coordinator};
use relay::runlog::{decode_segments, replay, MemSink};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::scenario::faults::FaultConfig;

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

fn modes() -> [(&'static str, RoundMode); 3] {
    [
        ("oc", RoundMode::OverCommit { factor: 1.3 }),
        ("dl", RoundMode::Deadline { deadline: 2.0 }),
        ("async", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }),
    ]
}

/// Straggler-rich DynAvail cell (mirrors the golden-baseline matrix) so
/// stale deliveries — the sync duplicate site — actually occur.
fn dup_cfg(mode: RoundMode, duplicate: f64, seed: u64) -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 14,
        rounds: 6,
        target_participants: 4,
        mode,
        avail: AvailMode::DynAvail,
        selector: "random".into(),
        use_saa: true,
        staleness_threshold: Some(3),
        mean_samples: 8,
        test_per_class: 4,
        eval_every: 2,
        cooldown_rounds: 1,
        min_round_duration: 0.0,
        lr: 0.1,
        seed,
        faults: FaultConfig { duplicate, ..Default::default() },
        ..Default::default()
    }
}

/// spent == aggregated + wasted after the run-end sweep, duplicate-heavy,
/// all three engines, several seeds.
#[test]
fn duplicate_heavy_accounting_identity_holds() {
    for (name, mode) in modes() {
        for seed in [1u64, 7, 42] {
            let cfg = dup_cfg(mode, 0.9, seed);
            let mut coord = Coordinator::new(cfg, exec())
                .unwrap_or_else(|e| panic!("{name}/seed{seed}: construct failed: {e:#}"));
            coord.run().unwrap_or_else(|e| panic!("{name}/seed{seed}: run failed: {e:#}"));
            let (spent, agg, wasted) = coord.accounting_totals();
            assert!(
                (spent - (agg + wasted)).abs() <= 1e-6 * spent.max(1.0),
                "{name}/seed{seed}: accounting identity broken under duplicates: \
                 spent {spent} != aggregated {agg} + wasted {wasted}"
            );
        }
    }
}

/// Duplicates only count faults: the trajectory with duplicate=0.9 must be
/// bitwise identical to the duplicate-free one in every field except
/// `faults` — and across the matrix the fault counter must actually move
/// (the audit would be vacuous if no duplicate ever fired).
#[test]
fn duplicates_touch_only_the_fault_counter() {
    let mut dup_faults = 0usize;
    let mut clean_faults = 0usize;
    let mut delivered = 0usize;
    for (name, mode) in modes() {
        for seed in [1u64, 7, 42] {
            let run = |duplicate: f64| {
                let mut coord = Coordinator::new(dup_cfg(mode, duplicate, seed), exec())
                    .unwrap_or_else(|e| panic!("{name}/seed{seed}: construct failed: {e:#}"));
                coord.run().unwrap_or_else(|e| panic!("{name}/seed{seed}: run failed: {e:#}"))
            };
            let (heavy, clean) = (run(0.9), run(0.0));
            assert_eq!(heavy.rounds.len(), clean.rounds.len());
            for (a, b) in heavy.rounds.iter().zip(clean.rounds.iter()) {
                let at = format!("{name}/seed{seed} round {}", a.round);
                assert_eq!(a.round, b.round);
                assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{at}: sim_time");
                assert_eq!(
                    a.round_duration.to_bits(),
                    b.round_duration.to_bits(),
                    "{at}: round_duration"
                );
                assert_eq!(a.selected, b.selected, "{at}: selected");
                assert_eq!(a.fresh_updates, b.fresh_updates, "{at}: fresh_updates");
                assert_eq!(a.stale_updates, b.stale_updates, "{at}: stale_updates");
                assert_eq!(a.dropouts, b.dropouts, "{at}: dropouts");
                assert_eq!(a.discarded, b.discarded, "{at}: discarded");
                assert_eq!(
                    a.cum_resource_secs.to_bits(),
                    b.cum_resource_secs.to_bits(),
                    "{at}: cum_resource_secs"
                );
                assert_eq!(
                    a.cum_waste_secs.to_bits(),
                    b.cum_waste_secs.to_bits(),
                    "{at}: cum_waste_secs"
                );
                assert_eq!(
                    a.train_loss.map(f64::to_bits),
                    b.train_loss.map(f64::to_bits),
                    "{at}: train_loss"
                );
                assert_eq!(
                    a.test_accuracy.map(f64::to_bits),
                    b.test_accuracy.map(f64::to_bits),
                    "{at}: test_accuracy"
                );
                assert!(a.faults >= b.faults, "{at}: duplicate run lost faults");
                dup_faults += a.faults;
                clean_faults += b.faults;
                delivered += a.fresh_updates + a.stale_updates;
            }
        }
    }
    assert!(delivered > 0, "matrix produced no deliveries at all — vacuous audit");
    assert!(
        dup_faults > clean_faults,
        "duplicate=0.9 never fired across the whole matrix \
         ({dup_faults} vs {clean_faults} faults over {delivered} deliveries)"
    );
}

/// The replay oracle must survive duplicate-heavy streams too: the logged
/// FaultDecision events must reconstruct the same fault counters.
#[test]
fn duplicate_heavy_replay_is_byte_identical() {
    for (name, mode) in modes() {
        let cfg = dup_cfg(mode, 0.9, 7);
        let sink = MemSink::default();
        let result = run_experiment_logged(cfg, exec(), Box::new(sink.clone()))
            .unwrap_or_else(|e| panic!("{name}: logged run failed: {e:#}"));
        let (events, stats) = decode_segments(&sink.segments());
        assert!(stats.clean, "{name}: log did not decode cleanly: {:?}", stats.note);
        let replayed = replay(&events).unwrap_or_else(|e| panic!("{name}: replay failed: {e:#}"));
        assert_eq!(
            replayed.to_json().to_string(),
            result.to_json().to_string(),
            "{name}: replay diverged under duplicate-heavy faults"
        );
    }
}
