//! The event-kernel equivalence suite: for a grid of OC/DL ×
//! AllAvail/DynAvail × selector configs, the refactored kernel-driven
//! engine (`coordinator::engine`) must produce `ExperimentResult` JSON that
//! is **byte-identical** to the pre-refactor monolithic round loop, which
//! is kept frozen in-tree as `coordinator::reference` (this container image
//! has no way to replay historical binaries, so the oracle is the frozen
//! source itself, executing the exact same floating-point kernels).
//!
//! Golden files: every cell can additionally be pinned to a committed
//! golden output under `tests/golden/`. Regenerate with
//! `RELAY_WRITE_GOLDEN=1 cargo test --test kernel_equivalence`; whenever a
//! golden file exists for a cell, the kernel engine's bytes are compared
//! against it too, so accidental behavioral drift in *either* engine fails
//! the suite.

use std::path::PathBuf;
use std::sync::Arc;

use relay::aggregation::scaling::ScalingRule;
use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::{run_experiment, run_reference_experiment};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::scenario::faults::FaultConfig;

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// Small but straggler-rich base: no round-duration floor and a tight
/// deadline, so the stale-delivery path (the part the kernel replaced) is
/// exercised hard in every DL cell.
fn tiny_base() -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 16,
        rounds: 6,
        target_participants: 4,
        mean_samples: 8,
        test_per_class: 4,
        eval_every: 2,
        cooldown_rounds: 1,
        min_round_duration: 0.0,
        lr: 0.1,
        use_saa: true,
        staleness_threshold: Some(3),
        scaling: ScalingRule::Relay { beta: 0.35 },
        ..Default::default()
    }
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Run one cell through both engines and assert bytewise equality (and
/// equality against the committed golden output, when present).
fn check_cell(label: &str, cfg: ExpConfig) {
    let reference = run_reference_experiment(cfg.clone(), exec())
        .unwrap_or_else(|e| panic!("cell '{label}': reference engine failed: {e:#}"));
    let kernel = run_experiment(cfg, exec())
        .unwrap_or_else(|e| panic!("cell '{label}': kernel engine failed: {e:#}"));
    let ref_json = reference.to_json().to_string();
    let kern_json = kernel.to_json().to_string();
    assert_eq!(
        ref_json, kern_json,
        "cell '{label}': event-kernel engine diverged from the frozen pre-refactor loop"
    );
    let path = golden_dir().join(format!("{label}.json"));
    if std::env::var("RELAY_WRITE_GOLDEN").is_ok() {
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, &ref_json).unwrap();
    } else if path.exists() {
        let golden = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            golden, kern_json,
            "cell '{label}': diverged from committed golden output {path:?}"
        );
    }
}

/// The acceptance grid: 4 selectors × {OC, DL} × {AllAvail, DynAvail}.
#[test]
fn oc_dl_grid_matches_reference_byte_for_byte() {
    for sel in ["random", "oort", "priority", "safa"] {
        for (mode_name, mode) in [
            ("oc1.3", RoundMode::OverCommit { factor: 1.3 }),
            ("dl2", RoundMode::Deadline { deadline: 2.0 }),
        ] {
            for (avail_name, avail) in [
                ("all", AvailMode::AllAvail),
                ("dyn", AvailMode::DynAvail),
            ] {
                let mut cfg = tiny_base();
                cfg.selector = sel.into();
                cfg.mode = mode;
                cfg.avail = avail;
                let label = format!("{sel}-{mode_name}-{avail_name}");
                cfg.label = label.clone();
                check_cell(&label, cfg);
            }
        }
    }
}

/// The full RELAY stack (IPS + SAA + APT): APT's straggler probe now walks
/// the kernel's pending delivery events — its target math must not move.
#[test]
fn relay_full_stack_matches_reference() {
    let mut cfg = tiny_base().relay();
    cfg.mode = RoundMode::Deadline { deadline: 2.0 };
    cfg.avail = AvailMode::DynAvail;
    cfg.rounds = 8;
    cfg.label = "relay-dl2-dyn".into();
    check_cell("relay-dl2-dyn", cfg);
}

/// Without SAA every straggler is waste-accounted up front (the doomed-skip
/// path) — none of that bookkeeping may shift.
#[test]
fn no_saa_matches_reference() {
    let mut cfg = tiny_base();
    cfg.use_saa = false;
    cfg.staleness_threshold = None;
    cfg.mode = RoundMode::Deadline { deadline: 2.0 };
    cfg.avail = AvailMode::AllAvail;
    cfg.label = "nosaa-dl2-all".into();
    check_cell("nosaa-dl2-all", cfg);
}

/// Unbounded staleness (the RELAY default) keeps deliveries pending across
/// many rounds — the longest-lived kernel events.
#[test]
fn unbounded_staleness_matches_reference() {
    let mut cfg = tiny_base();
    cfg.staleness_threshold = None;
    cfg.mode = RoundMode::OverCommit { factor: 1.3 };
    cfg.avail = AvailMode::AllAvail;
    cfg.rounds = 8;
    cfg.label = "unbounded-oc-all".into();
    check_cell("unbounded-oc-all", cfg);
}

/// Fault-injected cells: the deterministic fault model (flap / crash /
/// delay / corrupt / duplicate) is threaded through both engines as a
/// sanctioned joint edit — every fault must burn and account identically,
/// byte for byte, across OC/DL × AllAvail/DynAvail.
#[test]
fn fault_injected_cells_match_reference() {
    let crashy = FaultConfig {
        flap: 0.2,
        crash: 0.4,
        fault_seed: 7,
        ..Default::default()
    };
    let lossy = FaultConfig {
        corrupt: 0.35,
        duplicate: 0.3,
        delay: 0.4,
        delay_secs: 5.0,
        fault_seed: 11,
        ..Default::default()
    };
    for (fname, faults, selector) in
        [("crashy", crashy, "oort"), ("lossy", lossy, "priority")]
    {
        for (mode_name, mode) in [
            ("oc1.3", RoundMode::OverCommit { factor: 1.3 }),
            ("dl2", RoundMode::Deadline { deadline: 2.0 }),
        ] {
            for (avail_name, avail) in [
                ("all", AvailMode::AllAvail),
                ("dyn", AvailMode::DynAvail),
            ] {
                let mut cfg = tiny_base();
                cfg.selector = selector.into();
                cfg.mode = mode;
                cfg.avail = avail;
                cfg.faults = faults;
                let label = format!("faults-{fname}-{mode_name}-{avail_name}");
                cfg.label = label.clone();
                check_cell(&label, cfg);
            }
        }
    }
}

/// SAFA+O runs the two-pass oracle protocol on both engines: the probe
/// pass's aggregated-stale plan must transfer identically.
#[test]
fn safa_oracle_matches_reference() {
    let mut cfg = tiny_base();
    cfg.selector = "safa".into();
    cfg.staleness_threshold = Some(1);
    cfg.oracle = true;
    cfg.mode = RoundMode::Deadline { deadline: 2.0 };
    cfg.avail = AvailMode::AllAvail;
    cfg.label = "safa-oracle-dl2-all".into();
    check_cell("safa-oracle-dl2-all", cfg);
}
