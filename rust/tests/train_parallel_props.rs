//! PR 7 battery: the intra-round training pool must be invisible in the
//! results. Every cell — sync OC/DL, buffered-async, fault-injected
//! presets — must produce byte-identical `ExperimentResult` JSON at any
//! `train_workers` width, match the frozen serial reference engine where
//! it applies, and keep the run log replay oracle exact. A sleep-injecting
//! executor additionally forces adversarial out-of-order completion
//! through a real engine cell to pin the fixed reduction order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::{run_experiment, run_experiment_logged, run_reference_experiment};
use relay::runlog::{decode_segments, replay, MemSink};
use relay::runtime::{builtin_variant, Executor, NativeExecutor, TrainOut, VariantInfo};

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// Straggler-rich DynAvail base (mirrors the golden-baseline cells): small
/// enough to run each width in well under a second, rich enough to hit
/// selection, staleness, and churn.
fn cell_cfg(selector: &str, mode: RoundMode) -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 14,
        rounds: 5,
        target_participants: 4,
        mode,
        avail: AvailMode::DynAvail,
        selector: selector.into(),
        use_saa: true,
        staleness_threshold: Some(3),
        mean_samples: 8,
        test_per_class: 4,
        eval_every: 2,
        cooldown_rounds: 1,
        min_round_duration: 0.0,
        lr: 0.1,
        ..Default::default()
    }
}

/// Run `cfg` at the given training-pool width (sweep workers pinned to 1).
fn run_at_width(cfg: &ExpConfig, train_workers: usize, ex: Arc<dyn Executor>) -> String {
    let mut c = cfg.clone();
    c.workers = 1;
    c.train_workers = train_workers;
    run_experiment(c, ex)
        .unwrap_or_else(|e| panic!("cell '{}' @ width {train_workers} failed: {e:#}", cfg.label))
        .to_json()
        .to_string()
}

/// Sync and async cells across every round mode: widths 1/2/8 must agree
/// byte-for-byte, and the sync cells must also equal the frozen serial
/// reference engine (the pre-parallelism oracle).
#[test]
fn cells_are_byte_identical_across_train_worker_widths() {
    let modes = [
        ("oc", RoundMode::OverCommit { factor: 1.3 }),
        ("dl", RoundMode::Deadline { deadline: 2.0 }),
        ("async", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }),
    ];
    for selector in ["random", "oort", "safa"] {
        for (mode_name, mode) in modes.iter() {
            let mut cfg = cell_cfg(selector, *mode);
            cfg.label = format!("tp-{selector}-{mode_name}");
            let serial = run_at_width(&cfg, 1, exec());
            for width in [2usize, 8] {
                assert_eq!(
                    run_at_width(&cfg, width, exec()),
                    serial,
                    "cell '{}': train_workers {width} diverged from serial",
                    cfg.label
                );
            }
            if !matches!(mode, RoundMode::Async { .. }) {
                let mut rc = cfg.clone();
                rc.workers = 1;
                rc.train_workers = 8;
                let reference = run_reference_experiment(rc, exec())
                    .unwrap_or_else(|e| panic!("reference '{}' failed: {e:#}", cfg.label));
                assert_eq!(
                    reference.to_json().to_string(),
                    serial,
                    "cell '{}': frozen serial reference diverged from the pooled engine",
                    cfg.label
                );
            }
        }
    }
}

/// Fault-injected scenario presets (crashes, corruption, transit delays,
/// duplicates — sync and async) shrunk to test scale: the training pool
/// must stay invisible even on the failure paths.
#[test]
fn fault_injected_presets_are_byte_identical_across_widths() {
    for name in ["crash-storm", "stale-storm", "byzantine-lite"] {
        let preset = relay::scenario::by_name(name)
            .unwrap_or_else(|| panic!("preset '{name}' not registered"));
        let mut cfg = preset.cfg;
        cfg.total_learners = 24;
        cfg.rounds = 6;
        cfg.eval_every = 3;
        let serial = run_at_width(&cfg, 1, exec());
        for width in [2usize, 8] {
            assert_eq!(
                run_at_width(&cfg, width, exec()),
                serial,
                "preset '{name}': train_workers {width} diverged from serial"
            );
        }
    }
}

/// A logged run at width 8 must leave the bytes untouched, decode cleanly,
/// and replay to the exact serial JSON — i.e. the pool perturbs neither the
/// result nor the event stream it is derived from.
#[test]
fn runlog_replay_is_byte_identical_at_width_eight() {
    let mut cfg = cell_cfg("priority", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) });
    cfg.label = "tp-runlog-async".into();
    let serial = run_at_width(&cfg, 1, exec());

    let mut lc = cfg.clone();
    lc.workers = 1;
    lc.train_workers = 8;
    let sink = MemSink::default();
    let logged = run_experiment_logged(lc, exec(), Box::new(sink.clone()))
        .expect("logged width-8 run failed");
    assert_eq!(
        logged.to_json().to_string(),
        serial,
        "enabling the run log at width 8 perturbed the result bytes"
    );
    let (events, stats) = decode_segments(&sink.segments());
    assert!(stats.clean, "width-8 run log did not decode cleanly: {:?}", stats.note);
    let replayed = replay(&events).expect("width-8 replay failed");
    assert_eq!(
        replayed.to_json().to_string(),
        serial,
        "width-8 replay oracle diverged from the serial engine output"
    );
}

/// Executor wrapper that delegates all math untouched but sleeps a varying,
/// call-indexed amount inside `train_step` — so pool workers finish out of
/// submission order on purpose.
struct SleepyExec {
    inner: NativeExecutor,
    calls: AtomicUsize,
}

impl SleepyExec {
    fn new() -> SleepyExec {
        SleepyExec {
            inner: NativeExecutor::new(builtin_variant("tiny")),
            calls: AtomicUsize::new(0),
        }
    }
}

impl Executor for SleepyExec {
    fn variant(&self) -> &VariantInfo {
        self.inner.variant()
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        self.inner.init_params(seed)
    }

    fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        lr: f32,
    ) -> Result<TrainOut> {
        // pseudo-random 0..4.4ms stagger keyed on global call order: early
        // submissions routinely outlive later ones, inverting completion
        // order inside the pool.
        let n = self.calls.fetch_add(1, Ordering::Relaxed);
        std::thread::sleep(Duration::from_micros(((n * 97 + 13) % 23) as u64 * 200));
        self.inner.train_step(params, x, y, mask, lr)
    }

    fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32], mask: &[f32]) -> Result<(f32, f32)> {
        self.inner.eval_batch(params, x, y, mask)
    }

    fn agg_combine(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        self.inner.agg_combine(updates, weights)
    }

    fn agg_dev(&self, fresh: &[f32], stale: &[&[f32]]) -> Result<Vec<f32>> {
        self.inner.agg_dev(fresh, stale)
    }
}

/// Adversarial completion order through a real engine cell: with workers
/// sleeping call-indexed amounts, a width-8 pool completes jobs in a
/// scrambled order — the committed outcomes (and hence the bytes) must not
/// notice.
#[test]
fn adversarial_completion_order_cannot_reorder_commits() {
    for (label, mode) in [
        ("tp-sleepy-oc", RoundMode::OverCommit { factor: 1.3 }),
        ("tp-sleepy-async", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }),
    ] {
        let mut cfg = cell_cfg("oort", mode);
        cfg.label = label.into();
        let serial = run_at_width(&cfg, 1, exec());
        let sleepy = Arc::new(SleepyExec::new());
        let scrambled = run_at_width(&cfg, 8, Arc::clone(&sleepy) as Arc<dyn Executor>);
        assert!(
            sleepy.calls.load(Ordering::Relaxed) > 0,
            "cell '{label}': sleepy executor was never exercised"
        );
        assert_eq!(
            scrambled, serial,
            "cell '{label}': adversarial completion order leaked into the result bytes"
        );
    }
}
