//! Replay the committed fuzz corpus (`tests/corpus/*.json`) on every push:
//! each entry is a shrunk scenario config that once witnessed (or guards
//! against) an engine bug, re-run through the fuzz harness's full invariant
//! battery — JSON validity, structural invariants, the accounting identity,
//! byte-determinism across 1-vs-8 workers, and the engine-vs-frozen-
//! reference differential for sync modes.
//!
//! Also exercises the find → shrink → persist pipeline end to end on a
//! deliberately planted invariant violation (`sabotage_check`), proving
//! the shrinker lands on a locally-minimal replayable repro.

use std::path::PathBuf;

use relay::config::ExpConfig;
use relay::scenario::fuzz::{
    check_case, corpus_entries, sabotage_check, sample_config, shrink, shrink_transforms,
    write_corpus_entry,
};
use relay::util::rng::Rng;

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/corpus")
}

/// Every committed corpus entry must replay clean — including byte-identical
/// output across 1 vs 8 workers (check_case runs both).
#[test]
fn committed_corpus_replays_clean() {
    let entries = corpus_entries(&corpus_dir()).unwrap();
    assert!(
        entries.len() >= 4,
        "committed corpus went missing (found {} entries)",
        entries.len()
    );
    for (path, cfg, _failure) in entries {
        if let Some(why) = check_case(&cfg) {
            panic!("corpus entry {} regressed: {why}", path.display());
        }
    }
}

/// The acceptance pipeline: a deliberately seeded invariant violation is
/// found, shrunk to a locally-minimal scenario config, persisted, and
/// loaded back byte-identically.
#[test]
fn sabotage_pipeline_finds_shrinks_and_persists() {
    let root = Rng::new(0xBAD_5EED);
    let mut found: Option<ExpConfig> = None;
    for iter in 0..300u64 {
        let mut rng = root.stream(iter);
        let cfg = sample_config(&mut rng, true);
        if sabotage_check(&cfg).is_some() {
            found = Some(cfg);
            break;
        }
    }
    let cfg = found.expect("300 smoke samples should include a stale-aggregating cell");
    let mut fails = |c: &ExpConfig| sabotage_check(c);
    let shrunk = shrink(&cfg, &mut fails);
    assert!(
        sabotage_check(&shrunk).is_some(),
        "the shrunk config must still violate the planted invariant"
    );
    assert!(shrunk.total_learners <= cfg.total_learners);
    assert!(shrunk.rounds <= cfg.rounds);
    // local minimality: every further simplification is a no-op, invalid,
    // or makes the violation disappear (this is exactly the shrink loop's
    // fixpoint condition, re-checked independently)
    for t in shrink_transforms() {
        let cand = t(&shrunk);
        if cand.to_json().to_string() != shrunk.to_json().to_string()
            && cand.validate().is_ok()
        {
            assert!(
                sabotage_check(&cand).is_none(),
                "shrunk config is not locally minimal"
            );
        }
    }
    // the repro persists and loads back byte-identically
    let dir = std::env::temp_dir().join(format!("relay-corpus-demo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let path = write_corpus_entry(&dir, &shrunk, "sabotage demo").unwrap();
    let entries = corpus_entries(&dir).unwrap();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].0, path);
    assert_eq!(entries[0].1.to_json().to_string(), shrunk.to_json().to_string());
    assert_eq!(entries[0].2, "sabotage demo");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The fault-injected scenario presets pass the full battery: accounting
/// identity closed in both the sync and async engines, reference-equal on
/// sync modes, worker-invariant everywhere (scaled down for test speed).
#[test]
fn fault_presets_pass_the_full_invariant_battery() {
    for name in ["flaky-fleet", "byzantine-lite", "stale-storm"] {
        let mut cfg = relay::scenario::by_name(name)
            .unwrap_or_else(|| panic!("preset {name} vanished"))
            .cfg;
        cfg.total_learners = 20;
        cfg.rounds = 4;
        cfg.target_participants = 4;
        cfg.mean_samples = 8;
        cfg.test_per_class = 2;
        if let Some(why) = check_case(&cfg) {
            panic!("{name}: {why}");
        }
    }
}
