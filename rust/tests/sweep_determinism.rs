//! Sweep-engine integration tests: the aggregated grid report must be
//! byte-identical regardless of worker count (experiment-level parallelism
//! must never leak into results), and grid bookkeeping must match the spec.

use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::data::partition::PartitionScheme;
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::sweep::{run_grid, GridSpec, SweepOpts};

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

fn tiny_base() -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 16,
        rounds: 5,
        target_participants: 4,
        mean_samples: 8,
        test_per_class: 4,
        eval_every: 2,
        // default 5-round cooldown starves a 16-learner population (safa
        // selects everyone); 1 keeps every selector active each round pair
        cooldown_rounds: 1,
        lr: 0.1,
        ..Default::default()
    }
}

/// The acceptance grid: 4 selectors x 2 round modes x 3 seeds = 24 runs.
fn paper_grid() -> GridSpec {
    GridSpec {
        label: "det".into(),
        selectors: ["random", "oort", "priority", "safa"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        modes: vec![
            RoundMode::OverCommit { factor: 1.3 },
            RoundMode::Deadline { deadline: 40.0 },
        ],
        avails: vec![AvailMode::AllAvail],
        partitions: vec![PartitionScheme::UniformIid],
        coord_shards: vec![0],
        jobs: vec![1],
        seeds: vec![1, 1001, 2001],
        base: tiny_base(),
    }
}

#[test]
fn grid_report_byte_identical_across_worker_counts() {
    let spec = paper_grid();
    assert_eq!(spec.total_runs(), 24);
    let a = run_grid(&spec, exec(), &SweepOpts { workers: 1, progress: false }).unwrap();
    let b = run_grid(&spec, exec(), &SweepOpts { workers: 8, progress: false }).unwrap();
    assert_eq!(a.cells.len(), 8);
    assert_eq!(a.runs, 24);
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "aggregated report must not depend on worker count"
    );
}

#[test]
fn grid_cells_carry_meaningful_aggregates() {
    let spec = paper_grid();
    let r = run_grid(&spec, exec(), &SweepOpts { workers: 4, progress: false }).unwrap();
    for c in &r.cells {
        assert_eq!(c.seeds, 3, "{}", c.label);
        assert!(
            c.mean_resource_hours > 0.0,
            "{}: AllAvail cells must spend resources",
            c.label
        );
        let acc = c
            .mean_accuracy
            .unwrap_or_else(|| panic!("{}: eval_every=2 over 5 rounds must eval", c.label));
        assert!((0.0..=1.0).contains(&acc), "{}: acc {acc}", c.label);
        assert!(!c.selector.is_empty() && !c.mode.is_empty());
    }
}

#[test]
fn dyn_avail_grid_aggregates_without_panicking() {
    let mut spec = GridSpec::new(tiny_base());
    spec.selectors = vec!["random".into(), "relay".into()];
    spec.avails = vec![AvailMode::DynAvail];
    spec.seeds = vec![7, 1007];
    let r = run_grid(&spec, exec(), &SweepOpts { workers: 4, progress: false }).unwrap();
    assert_eq!(r.runs, 4);
    assert_eq!(r.cells.len(), 2);
    for c in &r.cells {
        assert_eq!(c.avail, "dyn");
        // tiny DynAvail populations may fail every round; the aggregates
        // must still be well-formed (no NaN leaking into the JSON)
        let json = c.to_json().to_string();
        assert!(!json.contains("NaN"), "{json}");
    }
}

#[test]
fn async_grid_byte_identical_across_worker_counts() {
    // the buffered-async engine must be a pure function of its config too:
    // `relay sweep` over async cells at workers 1 vs 8 returns one byte
    // stream
    let mut spec = GridSpec::new(tiny_base());
    spec.label = "async-det".into();
    spec.selectors = vec!["random".into(), "priority".into()];
    spec.modes = vec![RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }];
    spec.seeds = vec![1, 1001];
    let a = run_grid(&spec, exec(), &SweepOpts { workers: 1, progress: false }).unwrap();
    let b = run_grid(&spec, exec(), &SweepOpts { workers: 8, progress: false }).unwrap();
    assert_eq!(a.runs, 4);
    assert_eq!(a.cells.len(), 2);
    for c in &a.cells {
        assert_eq!(c.mode, "async3s4", "{}", c.label);
        // tiny DynAvail populations may burn every slot; the aggregates
        // must still be well-formed (no NaN leaking into the JSON)
        let json = c.to_json().to_string();
        assert!(!json.contains("NaN"), "{json}");
    }
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "async sweep report must not depend on worker count"
    );
}

#[test]
fn report_roundtrips_to_disk() {
    let mut spec = GridSpec::new(tiny_base());
    spec.seeds = vec![3];
    let r = run_grid(&spec, exec(), &SweepOpts { workers: 1, progress: false }).unwrap();
    let path = std::env::temp_dir().join("relay_sweep_test.json");
    r.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text, r.to_json().to_string());
    let parsed = relay::util::json::Json::parse(&text).unwrap();
    assert_eq!(parsed.get("format").and_then(|f| f.as_str()), Some("relay-sweep-v1"));
    std::fs::remove_file(path).ok();
}
