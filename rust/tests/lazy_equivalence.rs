//! Lazy-vs-eager equivalence for the scale refactor: lazily-generated
//! traces and forecasters must be bit-identical to eager materialization,
//! whole experiments must produce identical results, and a 100k-learner
//! DynAvail coordinator must construct without touching a single trace.

use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::{run_experiment, run_experiment_eager, Coordinator};
use relay::forecast::SeasonalForecaster;
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::trace::{LazyTraceSet, TraceConfig, TraceSet};

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

#[test]
fn lazy_sessions_bit_identical_to_eager() {
    for seed in [0u64, 9, 1234, 0xFFFF_FFFF_FFFF] {
        let eager = TraceSet::generate(50, seed, TraceConfig::default());
        let lazy = LazyTraceSet::new(50, seed, TraceConfig::default());
        // touch in reverse order to prove per-learner independence
        for l in (0..50).rev() {
            assert_eq!(
                eager.sessions[l].as_slice(),
                lazy.sessions(l),
                "seed {seed} learner {l}"
            );
        }
    }
    // the regular-charger config (nightly block) too
    let eager = TraceSet::generate(20, 5, TraceConfig::regular());
    let lazy = LazyTraceSet::new(20, 5, TraceConfig::regular());
    for l in 0..20 {
        assert_eq!(eager.sessions[l].as_slice(), lazy.sessions(l));
    }
}

#[test]
fn lazy_forecaster_probs_match_eager() {
    let eager = TraceSet::generate(10, 3, TraceConfig::default());
    let lazy = LazyTraceSet::new(10, 3, TraceConfig::default());
    for l in 0..10 {
        let fe = SeasonalForecaster::train_on_week(&eager.sample_series(l, 1800.0), 1800.0);
        let fl = SeasonalForecaster::train_on_week(&lazy.sample_series(l, 1800.0), 1800.0);
        for h in 0..168 {
            let (a, b) = (h as f64 * 3600.0, h as f64 * 3600.0 + 7200.0);
            assert_eq!(fe.prob_slot(a, b), fl.prob_slot(a, b), "learner {l} hour {h}");
        }
    }
}

#[test]
fn experiment_results_identical_lazy_vs_eager() {
    let cfg = ExpConfig {
        variant: "tiny".into(),
        total_learners: 30,
        rounds: 10,
        target_participants: 5,
        avail: AvailMode::DynAvail,
        mode: RoundMode::Deadline { deadline: 80.0 },
        use_saa: true,
        mean_samples: 10,
        test_per_class: 4,
        eval_every: 2,
        lr: 0.1,
        ..Default::default()
    };
    let lazy = run_experiment(cfg.clone(), exec()).unwrap();
    let eager = run_experiment_eager(cfg, exec()).unwrap();
    assert_eq!(lazy.final_accuracy(), eager.final_accuracy());
    assert_eq!(lazy.rounds.len(), eager.rounds.len());
    for (a, b) in lazy.rounds.iter().zip(&eager.rounds) {
        assert_eq!(a.selected, b.selected, "round {}", a.round);
        assert_eq!(a.fresh_updates, b.fresh_updates, "round {}", a.round);
        assert_eq!(a.stale_updates, b.stale_updates, "round {}", a.round);
        assert_eq!(a.dropouts, b.dropouts, "round {}", a.round);
        assert_eq!(a.failed, b.failed, "round {}", a.round);
        assert_eq!(a.round_duration, b.round_duration, "round {}", a.round);
        assert_eq!(a.cum_resource_secs, b.cum_resource_secs, "round {}", a.round);
        assert_eq!(a.cum_waste_secs, b.cum_waste_secs, "round {}", a.round);
        assert_eq!(a.test_accuracy, b.test_accuracy, "round {}", a.round);
    }
}

#[test]
fn huge_population_constructs_without_materializing() {
    let cfg = ExpConfig {
        variant: "tiny".into(),
        total_learners: 100_000,
        rounds: 1,
        target_participants: 10,
        avail: AvailMode::DynAvail,
        mean_samples: 4,
        test_per_class: 2,
        eval_every: 1000,
        lr: 0.1,
        ..Default::default()
    };
    let coord = Coordinator::new(cfg, exec()).unwrap();
    assert_eq!(
        coord.materialized_traces(),
        0,
        "construction must not generate any learner trace"
    );
    assert_eq!(
        coord.trained_forecasters(),
        0,
        "construction must not train any forecaster"
    );
}

#[test]
fn forecasters_train_only_for_available_checkins() {
    let cfg = ExpConfig {
        variant: "tiny".into(),
        total_learners: 200,
        rounds: 2,
        target_participants: 5,
        avail: AvailMode::DynAvail,
        mean_samples: 6,
        test_per_class: 2,
        eval_every: 1000,
        lr: 0.1,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg, exec()).unwrap();
    let r = coord.run().unwrap();
    assert_eq!(r.rounds.len(), 2);
    // availability checks touch traces; forecasters are only trained for
    // learners that were actually available at a check-in window
    assert!(coord.materialized_traces() >= coord.trained_forecasters());
    assert!(
        coord.trained_forecasters() < 200,
        "charging traces are mostly-off; some learners must never be probed"
    );
}
