//! PR 9 battery: the coordinator shard count must be invisible in the
//! results. Every cell — sync OC/DL, buffered-async, fault-injected
//! presets — must produce byte-identical `ExperimentResult` JSON at any
//! `coord_shards` K (K=1 is the flat path), with the parallel per-shard
//! sync pass enabled (workers > 1), match the frozen flat reference
//! engine where it applies, and keep the run log replay oracle exact.

use std::sync::Arc;

use relay::config::{AvailMode, ExpConfig, RoundMode};
use relay::coordinator::{run_experiment, run_experiment_logged, run_reference_experiment};
use relay::runlog::{decode_segments, replay, MemSink};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// Straggler-rich DynAvail base (mirrors the golden-baseline cells): small
/// enough to run each K in well under a second, rich enough to hit
/// selection, staleness, cooldown churn, and busy-bucket expiry.
fn cell_cfg(selector: &str, mode: RoundMode) -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 14,
        rounds: 5,
        target_participants: 4,
        mode,
        avail: AvailMode::DynAvail,
        selector: selector.into(),
        use_saa: true,
        staleness_threshold: Some(3),
        mean_samples: 8,
        test_per_class: 4,
        eval_every: 2,
        cooldown_rounds: 1,
        min_round_duration: 0.0,
        lr: 0.1,
        ..Default::default()
    }
}

/// Run `cfg` at the given coordinator shard count on a multi-thread worker
/// pool, so the per-shard sync pass genuinely runs in parallel.
fn run_at_k(cfg: &ExpConfig, coord_shards: usize, ex: Arc<dyn Executor>) -> String {
    let mut c = cfg.clone();
    c.workers = 4;
    c.train_workers = 1;
    c.coord_shards = coord_shards;
    run_experiment(c, ex)
        .unwrap_or_else(|e| panic!("cell '{}' @ K={coord_shards} failed: {e:#}", cfg.label))
        .to_json()
        .to_string()
}

/// Sync and async cells across every round mode and selector: K in
/// {1, 2, 7, 16} must agree byte-for-byte, and the sync cells must also
/// equal the frozen reference engine (which stays flat, the oracle).
#[test]
fn cells_are_byte_identical_across_coord_shard_counts() {
    let modes = [
        ("oc", RoundMode::OverCommit { factor: 1.3 }),
        ("dl", RoundMode::Deadline { deadline: 2.0 }),
        ("async", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }),
    ];
    for selector in ["random", "oort", "safa"] {
        for (mode_name, mode) in modes.iter() {
            let mut cfg = cell_cfg(selector, *mode);
            cfg.label = format!("cs-{selector}-{mode_name}");
            let flat = run_at_k(&cfg, 1, exec());
            for k in [2usize, 7, 16] {
                assert_eq!(
                    run_at_k(&cfg, k, exec()),
                    flat,
                    "cell '{}': coord_shards {k} diverged from the flat path",
                    cfg.label
                );
            }
            if !matches!(mode, RoundMode::Async { .. }) {
                let mut rc = cfg.clone();
                rc.workers = 4;
                rc.train_workers = 1;
                rc.coord_shards = 7;
                let reference = run_reference_experiment(rc, exec())
                    .unwrap_or_else(|e| panic!("reference '{}' failed: {e:#}", cfg.label));
                assert_eq!(
                    reference.to_json().to_string(),
                    flat,
                    "cell '{}': frozen flat reference diverged from the sharded engine",
                    cfg.label
                );
            }
        }
    }
}

/// The priority/IPS selector exercises the hook-maintained per-bucket
/// ScoreIndex hardest (every eligible-set delta re-keys a tree entry):
/// shard-major hook forwarding must leave its trees byte-identical too.
#[test]
fn priority_selector_cells_are_byte_identical_across_k() {
    for (mode_name, mode) in [
        ("dl", RoundMode::Deadline { deadline: 2.0 }),
        ("async", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) }),
    ] {
        let mut cfg = cell_cfg("priority", mode);
        cfg.label = format!("cs-priority-{mode_name}");
        let flat = run_at_k(&cfg, 1, exec());
        for k in [2usize, 7, 16] {
            assert_eq!(
                run_at_k(&cfg, k, exec()),
                flat,
                "cell '{}': coord_shards {k} diverged from the flat path",
                cfg.label
            );
        }
    }
}

/// Fault-injected scenario presets (crashes, corruption, transit delays,
/// duplicates — sync and async) shrunk to test scale: sharding must stay
/// invisible on the failure paths too (quarantine cooldowns, crash churn).
#[test]
fn fault_injected_presets_are_byte_identical_across_k() {
    for name in ["crash-storm", "stale-storm", "byzantine-lite"] {
        let preset = relay::scenario::by_name(name)
            .unwrap_or_else(|| panic!("preset '{name}' not registered"));
        let mut cfg = preset.cfg;
        cfg.total_learners = 24;
        cfg.rounds = 6;
        cfg.eval_every = 3;
        let flat = run_at_k(&cfg, 1, exec());
        for k in [2usize, 7, 16] {
            assert_eq!(
                run_at_k(&cfg, k, exec()),
                flat,
                "preset '{name}': coord_shards {k} diverged from the flat path"
            );
        }
    }
}

/// A logged run at K=7 must leave the bytes untouched, decode cleanly, and
/// replay to the exact flat JSON — i.e. sharding perturbs neither the
/// result nor the event stream it is derived from.
#[test]
fn runlog_replay_is_byte_identical_at_k_seven() {
    let mut cfg = cell_cfg("priority", RoundMode::Async { buffer_k: 3, max_staleness: Some(4) });
    cfg.label = "cs-runlog-async".into();
    let flat = run_at_k(&cfg, 1, exec());

    let mut lc = cfg.clone();
    lc.workers = 4;
    lc.train_workers = 1;
    lc.coord_shards = 7;
    let sink = MemSink::default();
    let logged = run_experiment_logged(lc, exec(), Box::new(sink.clone()))
        .expect("logged K=7 run failed");
    assert_eq!(
        logged.to_json().to_string(),
        flat,
        "enabling the run log at K=7 perturbed the result bytes"
    );
    let (events, stats) = decode_segments(&sink.segments());
    assert!(stats.clean, "K=7 run log did not decode cleanly: {:?}", stats.note);
    let replayed = replay(&events).expect("K=7 replay failed");
    assert_eq!(
        replayed.to_json().to_string(),
        flat,
        "K=7 replay oracle diverged from the flat engine output"
    );
}

/// K=0 (autodetect) must behave exactly like some explicit K — i.e. the
/// autodetect only picks a K, it never changes behavior.
#[test]
fn autodetect_is_equivalent_to_explicit_k() {
    let mut cfg = cell_cfg("oort", RoundMode::OverCommit { factor: 1.3 });
    cfg.label = "cs-autodetect".into();
    let flat = run_at_k(&cfg, 1, exec());
    assert_eq!(
        run_at_k(&cfg, 0, exec()),
        flat,
        "coord_shards autodetect diverged from the flat path"
    );
}
