//! PR 10 battery: the multi-job coordinator over one shared fleet. Per-job
//! accounting must close (`spent == aggregated + wasted + in_flight`, with
//! nothing left in flight after the terminal sweep), fleet totals must be
//! the sum over jobs, no device may be busy for two jobs at once, the
//! output must be byte-identical at any `workers` × `coord_shards`, and a
//! logged run must replay byte-exactly through `replay_multijob`.

use std::sync::Arc;

use relay::config::ExpConfig;
use relay::jobs::{replay_multijob, run_jobset, run_jobset_logged, MultiJobResult};
use relay::runlog::{decode_segments, MemSink, RunEvent};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};

const REL_EPS: f64 = 1e-6;

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// A registered multi-job preset shrunk to test scale.
fn preset(name: &str) -> ExpConfig {
    let mut cfg = relay::scenario::by_name(name)
        .unwrap_or_else(|| panic!("preset '{name}' not registered"))
        .cfg;
    cfg.total_learners = cfg.total_learners.min(32);
    cfg.rounds = cfg.rounds.min(4);
    cfg.mean_samples = cfg.mean_samples.min(8);
    cfg.test_per_class = 2;
    cfg.eval_every = 2;
    cfg.validate().unwrap_or_else(|e| panic!("shrunk '{name}' invalid: {e:#}"));
    cfg
}

fn run(cfg: &ExpConfig, workers: usize, coord_shards: usize) -> MultiJobResult {
    let mut c = cfg.clone();
    c.workers = workers;
    c.train_workers = workers;
    c.coord_shards = coord_shards;
    run_jobset(c, exec())
        .unwrap_or_else(|e| panic!("jobset '{}' failed: {e:#}", cfg.label))
}

fn assert_books_close(cfg: &ExpConfig, r: &MultiJobResult) {
    let tol = |x: f64| REL_EPS * x.abs().max(1.0);
    assert_eq!(r.jobs.len(), cfg.jobs, "'{}': one summary per job", cfg.label);
    let (mut spent, mut agg, mut wasted, mut in_flight) = (0.0, 0.0, 0.0, 0.0);
    for job in &r.jobs {
        assert!(
            job.in_flight_secs.abs() <= tol(job.spent_secs),
            "'{}' job {}: {} in-flight seconds survived the terminal sweep",
            cfg.label,
            job.job,
            job.in_flight_secs
        );
        let closed = job.aggregated_secs + job.wasted_secs + job.in_flight_secs;
        assert!(
            (job.spent_secs - closed).abs() <= tol(job.spent_secs),
            "'{}' job {} identity broken: spent {} != aggregated {} + wasted {} + in-flight {}",
            cfg.label,
            job.job,
            job.spent_secs,
            job.aggregated_secs,
            job.wasted_secs,
            job.in_flight_secs
        );
        spent += job.spent_secs;
        agg += job.aggregated_secs;
        wasted += job.wasted_secs;
        in_flight += job.in_flight_secs;
    }
    for (name, fleet, sum) in [
        ("spent", r.fleet_spent_secs, spent),
        ("aggregated", r.fleet_aggregated_secs, agg),
        ("wasted", r.fleet_wasted_secs, wasted),
        ("in_flight", r.fleet_in_flight_secs, in_flight),
    ] {
        assert!(
            (fleet - sum).abs() <= tol(sum),
            "'{}': fleet {name} {fleet} != per-job sum {sum}",
            cfg.label
        );
    }
}

/// Both registered multi-job presets: per-job accounting identity closes,
/// fleet totals are the per-job sums, every job ran every round, and a
/// logged run decodes cleanly and replays byte-exactly.
#[test]
fn preset_accounting_closes_and_replay_is_exact() {
    for name in ["job-storm", "starved-low-priority"] {
        let cfg = preset(name);
        let r = run(&cfg, 1, 1);
        assert_books_close(&cfg, &r);
        for job in &r.jobs {
            assert_eq!(job.rounds.len(), cfg.rounds, "'{name}' job {}: round count", job.job);
        }
        let baseline = r.to_json().to_string();

        let sink = MemSink::default();
        let mut lc = cfg.clone();
        lc.workers = 1;
        lc.train_workers = 1;
        let logged = run_jobset_logged(lc, exec(), Box::new(sink.clone()))
            .unwrap_or_else(|e| panic!("logged '{name}' run failed: {e:#}"));
        assert_eq!(
            logged.to_json().to_string(),
            baseline,
            "'{name}': enabling the run log perturbed the result bytes"
        );
        let (events, stats) = decode_segments(&sink.segments());
        assert!(stats.clean, "'{name}' log did not decode cleanly: {:?}", stats.note);
        let replayed = replay_multijob(&events)
            .unwrap_or_else(|e| panic!("'{name}' replay failed: {e:#}"));
        assert_eq!(
            replayed.to_json().to_string(),
            baseline,
            "'{name}': replay diverged from the engine output"
        );
    }
}

/// Shared-fleet exclusivity: reconstruct every device's busy intervals from
/// the `JobSpawn` stream (a claim is `mark_busy_for(id, now + cost)`, where
/// cost is `dropped_after.unwrap_or(duration)`) and assert no two intervals
/// owned by *different* jobs overlap for the same learner.
#[test]
fn no_device_is_busy_for_two_jobs_at_once() {
    let cfg = preset("job-storm");
    let sink = MemSink::default();
    let mut lc = cfg.clone();
    lc.workers = 1;
    lc.train_workers = 1;
    run_jobset_logged(lc, exec(), Box::new(sink.clone())).expect("job-storm run failed");
    let (events, stats) = decode_segments(&sink.segments());
    assert!(stats.clean, "log did not decode cleanly: {:?}", stats.note);

    // learner -> [(job, start, end)]
    let mut busy: std::collections::HashMap<u64, Vec<(u64, f64, f64)>> =
        std::collections::HashMap::new();
    let mut spawns = 0usize;
    for ev in &events {
        if let RunEvent::JobSpawn { job, learner, now, duration, dropped_after, .. } = ev {
            let end = now + dropped_after.unwrap_or(*duration);
            busy.entry(*learner).or_default().push((*job, *now, end));
            spawns += 1;
        }
    }
    assert!(spawns > 0, "the storm preset must actually spawn tasks");

    for (learner, mut ivals) in busy {
        ivals.sort_by(|a, b| a.1.total_cmp(&b.1));
        for w in ivals.windows(2) {
            let (ja, _, end_a) = w[0];
            let (jb, start_b, _) = w[1];
            if ja != jb {
                assert!(
                    start_b >= end_a - 1e-9,
                    "learner {learner} busy for job {jb} at t={start_b} while still \
                     owned by job {ja} until t={end_a}"
                );
            }
        }
    }
}

/// The PR's acceptance bar: a four-job run is byte-identical at every
/// `workers` × `coord_shards` combination, and repeat runs of the same
/// config reproduce the same bytes.
#[test]
fn four_job_run_is_byte_identical_across_workers_and_shards() {
    let cfg = preset("job-storm");
    assert_eq!(cfg.jobs, 4);
    let baseline = run(&cfg, 1, 1).to_json().to_string();
    assert_eq!(
        run(&cfg, 1, 1).to_json().to_string(),
        baseline,
        "repeat run of the same config diverged"
    );
    for workers in [1usize, 8] {
        for shards in [1usize, 8] {
            assert_eq!(
                run(&cfg, workers, shards).to_json().to_string(),
                baseline,
                "workers={workers} coord_shards={shards} diverged from the 1/1 run"
            );
        }
    }
}

/// The acceptance cell at fleet scale: four jobs over one shared
/// 100k-learner lazy DynAvail fleet, byte-identical across
/// `workers {1,8}` × `coord-shards {1,8}`, books closed. Costs stay
/// test-sized because the population is lazy and per-event: only the
/// ~hundred selected devices ever train.
#[test]
fn four_jobs_over_a_100k_fleet_are_byte_identical() {
    let mut cfg = ExpConfig {
        variant: "tiny".into(),
        total_learners: 100_000,
        rounds: 2,
        target_participants: 20,
        mean_samples: 4,
        test_per_class: 2,
        eval_every: 1_000_000,
        lr: 0.1,
        min_round_duration: 0.0,
        ..Default::default()
    };
    cfg.jobs = 4;
    cfg.job_policy = "fair".into();
    cfg.job_modes = ["oc1.3", "dl40", "async3", "oc"].iter().map(|s| s.to_string()).collect();
    cfg.job_targets = vec![50, 30, 20, 10];
    cfg.label = "mj-100k".into();
    cfg.validate().expect("100k cell invalid");

    let r = run(&cfg, 1, 1);
    assert_books_close(&cfg, &r);
    let baseline = r.to_json().to_string();
    for (workers, shards) in [(1usize, 8usize), (8, 1), (8, 8)] {
        assert_eq!(
            run(&cfg, workers, shards).to_json().to_string(),
            baseline,
            "100k fleet: workers={workers} coord_shards={shards} diverged"
        );
    }
}

/// Strict-priority arbitration on an oversubscribed pool: the top-priority
/// job claims first at every arbitration point, so it must spend at least
/// as much fleet time as the bottom-priority job — which exists to starve.
#[test]
fn strict_priority_starves_the_low_priority_job() {
    let cfg = preset("starved-low-priority");
    assert_eq!(cfg.job_policy, "priority");
    let r = run(&cfg, 1, 1);
    assert_books_close(&cfg, &r);
    let top = &r.jobs[0];
    let bottom = &r.jobs[2];
    assert!(top.priority > bottom.priority, "preset must order priorities 0 > 2");
    assert!(
        top.spent_secs >= bottom.spent_secs,
        "priority arbitration inverted: top job spent {} < bottom job {}",
        top.spent_secs,
        bottom.spent_secs
    );
    assert!(
        top.unique_participants >= bottom.unique_participants,
        "top job reached {} devices, bottom reached {}",
        top.unique_participants,
        bottom.unique_participants
    );
}
