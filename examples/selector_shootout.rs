//! Selector shoot-out: all four strategies (Random, Oort, Priority/IPS,
//! SAFA) plus full RELAY on the same non-IID workload, printing the
//! resource-efficiency comparison the paper's §3 motivates.
//!
//!     cargo run --release --example selector_shootout [-- --backend native]

use std::sync::Arc;

use relay::config::{preset, AvailMode, ExpConfig, RoundMode};
use relay::coordinator::run_experiment;
use relay::data::partition::{LabelSkew, PartitionScheme};
use relay::runtime::{self, Backend};
use relay::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let backend = Backend::parse(&args.str_or("backend", "pjrt")).expect("backend");
    let exec = match backend {
        Backend::Pjrt => runtime::load_executor("artifacts", "speech", Backend::Pjrt)?,
        Backend::Native => Arc::new(runtime::NativeExecutor::new(
            runtime::builtin_variant("speech"),
        )),
    };

    let base = || -> ExpConfig {
        let mut c = preset("speech").unwrap();
        c.total_learners = args.usize_or("learners", 300);
        c.rounds = args.usize_or("rounds", 150);
        c.mode = RoundMode::Deadline { deadline: 100.0 };
        c.avail = AvailMode::DynAvail;
        c.partition = PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Uniform };
        c
    };

    let mut configs = Vec::new();
    for sel in ["random", "oort", "priority", "safa"] {
        let mut c = base().with_label(sel);
        c.selector = sel.into();
        if sel == "safa" {
            c.use_saa = true;
            c.staleness_threshold = Some(5);
            c.scaling = relay::aggregation::scaling::ScalingRule::Equal;
        }
        configs.push(c);
    }
    configs.push(base().relay().with_label("relay (ips+saa+apt)"));

    let mut results = Vec::new();
    for cfg in configs {
        let r = run_experiment(cfg, Arc::clone(&exec))?;
        println!("{}", r.summary());
        results.push(r);
    }
    println!("\naccuracy vs resources:");
    relay::figures::runner::print_series(&results, 6);
    Ok(())
}
