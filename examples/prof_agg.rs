use std::time::Instant;
fn main() {
    let exec = relay::runtime::load_executor("artifacts", "speech", relay::runtime::Backend::Pjrt).unwrap();
    let p = exec.variant().num_params;
    let rows: Vec<Vec<f32>> = (0..13).map(|i| vec![i as f32 * 0.01; p]).collect();
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let w = vec![0.077f32; 13];
    // warm
    exec.agg_combine(&refs, &w).unwrap();
    let t = Instant::now();
    for _ in 0..20 { exec.agg_combine(&refs, &w).unwrap(); }
    println!("agg_combine(13 rows): {:.1} ms", t.elapsed().as_secs_f64()*1000.0/20.0);
    let fresh = vec![0.5f32; p];
    exec.agg_dev(&fresh, &refs[..3]).unwrap();
    let t = Instant::now();
    for _ in 0..20 { exec.agg_dev(&fresh, &refs[..3]).unwrap(); }
    println!("agg_dev(3 rows): {:.1} ms", t.elapsed().as_secs_f64()*1000.0/20.0);
    // literal creation cost alone
    let stacked = vec![0f32; 64*p];
    let t = Instant::now();
    for _ in 0..20 {
        let l = xla::Literal::vec1(&stacked).reshape(&[64, p as i64]).unwrap();
        std::hint::black_box(l);
    }
    println!("literal 64xP create+reshape: {:.1} ms", t.elapsed().as_secs_f64()*1000.0/20.0);
}
