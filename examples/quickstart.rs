//! Quickstart: run RELAY (IPS + SAA + APT) on the speech benchmark stand-in
//! for a handful of rounds and print the accuracy trajectory.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use relay::config::{preset, AvailMode, RoundMode};
use relay::coordinator::run_experiment;
use relay::runtime::load_executor_or_native;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut cfg = preset("speech")?.relay().with_label("relay-quickstart");
    cfg.total_learners = 100;
    cfg.rounds = 60;
    cfg.target_participants = 10;
    cfg.mode = RoundMode::Deadline { deadline: 100.0 };
    cfg.avail = AvailMode::DynAvail;
    cfg.eval_every = 5;

    let exec = load_executor_or_native("artifacts", &cfg.variant);
    println!("backend loaded; running {} rounds x {} learners", cfg.rounds, cfg.total_learners);
    let result = run_experiment(cfg, Arc::clone(&exec))?;

    println!("\n round | sim time | resources | accuracy");
    for r in &result.rounds {
        if let Some(acc) = r.test_accuracy {
            println!(
                "{:>6} | {:>7.0}s | {:>8.2}h | {:>6.1}%",
                r.round,
                r.sim_time,
                r.cum_resource_secs / 3600.0,
                100.0 * acc
            );
        }
    }
    println!("\n{}", result.summary());
    println!("wallclock: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
