//! Availability forecasting demo (paper §5.2 + Algorithm 1's learner side):
//! generates a charging trace, trains the learner-side seasonal model and
//! the Prophet-substitute Fourier model, and reports forecast quality plus
//! example slot probabilities like those learners return at check-in.
//!
//!     cargo run --release --example availability_forecast

use relay::forecast::{evaluate_series, SeasonalForecaster};
use relay::trace::{TraceConfig, TraceSet, DAY, WEEK};
use relay::util::stats;

fn main() -> anyhow::Result<()> {
    // 1) the 5.2 protocol on a regular-charger population
    let devices = 137;
    let trace = TraceSet::generate(devices, 52, TraceConfig::regular());
    let step = 900.0;
    let mut r2s = Vec::new();
    for d in 0..devices {
        let week = trace.sample_series(d, step);
        let mut series = Vec::new();
        for _ in 0..4 {
            series.extend_from_slice(&week);
        }
        let times: Vec<f64> = (0..series.len()).map(|i| i as f64 * step).collect();
        let (r2, _, _) = evaluate_series(&times, &series);
        r2s.push(r2);
    }
    println!("Prophet-substitute on {} regular devices: mean R^2 = {:.3} (paper: 0.93)",
        devices, stats::mean(&r2s));

    // 2) the learner-side model used inside RELAY's IPS
    let trace = TraceSet::generate(5, 7, TraceConfig::default());
    println!("\nlearner-side seasonal forecaster (slot probabilities at check-in):");
    for l in 0..5 {
        let mut f = SeasonalForecaster::default();
        let series = trace.sample_series(l, 1800.0);
        for rep in 0..2 {
            for (i, &v) in series.iter().enumerate() {
                f.observe(rep as f64 * WEEK + i as f64 * 1800.0, v > 0.5);
            }
        }
        // probe the paper's slot (mu, 2mu) for mu = 100 s at a few times
        let mut row = Vec::new();
        for hour in [2.0, 10.0, 14.0, 22.0] {
            let t = hour * 3600.0;
            row.push(format!("{:>2.0}h:{:.2}", hour, f.prob_slot(t + 100.0, t + 200.0)));
        }
        println!("  learner {l}: {}", row.join("  "));
    }

    // 3) ground truth vs forecast for one device over a day
    let mut f = SeasonalForecaster::default();
    let series = trace.sample_series(0, 1800.0);
    for rep in 0..2 {
        for (i, &v) in series.iter().enumerate() {
            f.observe(rep as f64 * WEEK + i as f64 * 1800.0, v > 0.5);
        }
    }
    println!("\nlearner 0, hour-by-hour (truth / forecast):");
    for h in 0..24 {
        let t = h as f64 * 3600.0;
        let truth = trace.available(0, t);
        print!("{}", if truth { 'X' } else { '.' });
        let _ = f.prob_at(t);
    }
    println!("  <- trace day 0");
    for h in 0..24 {
        let t = h as f64 * 3600.0;
        print!("{}", if f.prob_at(t) > 0.5 { 'X' } else { '.' });
    }
    println!("  <- forecast");
    let _ = DAY;
    Ok(())
}
