//! End-to-end driver (the repo's headline validation run): the full RELAY
//! system — IPS + APT + SAA with Eq. 2 weights — training the speech
//! benchmark stand-in over a 1000-learner simulated population with dynamic
//! availability, real SGD through the AOT-compiled HLO artifacts on the
//! PJRT CPU client, against Oort and Random baselines.
//!
//!     make artifacts && cargo run --release --example speech_e2e
//!     (flags: --learners N --rounds N --backend native --seeds K)
//!
//! Logs the loss/accuracy curve per method and the final resource/waste
//! comparison; the run recorded in EXPERIMENTS.md used the defaults.

use std::sync::Arc;

use relay::config::{preset, AvailMode, ExpConfig, RoundMode};
use relay::coordinator::run_experiment;
use relay::data::partition::{LabelSkew, PartitionScheme};
use relay::runtime::{self, Backend};
use relay::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let learners = args.usize_or("learners", 1000);
    let rounds = args.usize_or("rounds", 300);
    let backend = Backend::parse(&args.str_or("backend", "pjrt")).expect("backend");

    let base = |label: &str| -> ExpConfig {
        let mut c = preset("speech").unwrap();
        c.label = label.into();
        c.total_learners = learners;
        c.rounds = rounds;
        c.target_participants = 10;
        c.mode = RoundMode::Deadline { deadline: 100.0 };
        c.avail = AvailMode::DynAvail;
        c.partition = PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Uniform };
        c.eval_every = 10;
        c
    };

    let exec = match backend {
        Backend::Pjrt => runtime::load_executor("artifacts", "speech", Backend::Pjrt)?,
        Backend::Native => Arc::new(runtime::NativeExecutor::new(
            runtime::builtin_variant("speech"),
        )),
    };

    let configs = vec![
        base("relay").relay(),
        {
            let mut c = base("oort");
            c.selector = "oort".into();
            c
        },
        {
            let mut c = base("random");
            c.selector = "random".into();
            c
        },
    ];

    let t0 = std::time::Instant::now();
    let mut results = Vec::new();
    for cfg in configs {
        let label = cfg.label.clone();
        println!("\n=== {} ({} learners, {} rounds, DL=100s, DynAvail, non-IID) ===", label, learners, rounds);
        let r = run_experiment(cfg, Arc::clone(&exec))?;
        println!(" round |  time(s) | res(h) | train loss | test loss | acc");
        for rec in &r.rounds {
            if let (Some(acc), Some(tl)) = (rec.test_accuracy, rec.test_loss) {
                println!(
                    "{:>6} | {:>8.0} | {:>6.2} | {:>10.3} | {:>9.3} | {:>5.1}%",
                    rec.round,
                    rec.sim_time,
                    rec.cum_resource_secs / 3600.0,
                    rec.train_loss.unwrap_or(f64::NAN),
                    tl,
                    100.0 * acc
                );
            }
        }
        println!("{}", r.summary());
        results.push(r);
    }

    println!("\n=== comparison (accuracy @ equal resources) ===");
    relay::figures::runner::print_series(&results, 6);
    std::fs::create_dir_all("results")?;
    relay::figures::runner::save(
        "speech_e2e",
        &results,
        &relay::figures::runner::FigureOpts::default(),
    )?;
    println!("wallclock: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
